"""Minimal stand-in for ``hypothesis`` so the suite runs without the dep.

The real package is preferred (``pip install -r requirements-dev.txt``); when
it is missing, :func:`install` registers this module as ``hypothesis`` /
``hypothesis.strategies`` in ``sys.modules`` *before* test modules import it
(conftest.py runs first).  It implements exactly the API surface the tests
use — ``@settings(max_examples=..., deadline=...)``, ``@given(**strategies)``,
the flat ``integers`` / ``floats`` / ``lists`` / ``tuples`` /
``sampled_from`` / ``just`` / ``one_of`` / ``builds`` strategies, and the
grammar combinators ``recursive`` / ``deferred`` / ``composite`` that
tests/strategies.py builds random Query ASTs with — by drawing deterministic
pseudo-random examples: example ``i`` of every test draws from
``random.Random(i)``, so failures reproduce.

No shrinking, no database, no adaptive search: this is a fallback that keeps
property tests *running* (as seeded fuzz tests), not a replacement.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types

DEFAULT_MAX_EXAMPLES = 20
_EXAMPLES_ATTR = "_fallback_max_examples"


class SearchStrategy:
    """A strategy is just a draw function over a ``random.Random``."""

    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


def integers(min_value=0, max_value=None) -> SearchStrategy:
    hi = (min_value + 1000) if max_value is None else max_value
    return SearchStrategy(lambda rng: rng.randint(min_value, hi))


def floats(min_value=0.0, max_value=1.0, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.uniform(min_value, max_value))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements))


def lists(elements: SearchStrategy, min_size=0, max_size=None) -> SearchStrategy:
    hi = min_size + 8 if max_size is None else max_size

    def draw(rng):
        return [elements.example(rng) for _ in range(rng.randint(min_size, hi))]

    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strategies))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def none() -> SearchStrategy:
    return just(None)


def one_of(*strategies) -> SearchStrategy:
    """Accepts varargs or a single iterable, like the real API."""
    if len(strategies) == 1 and not isinstance(strategies[0], SearchStrategy):
        strategies = tuple(strategies[0])
    return SearchStrategy(lambda rng: rng.choice(strategies).example(rng))


def _draw_arg(arg, rng):
    return arg.example(rng) if isinstance(arg, SearchStrategy) else arg


def builds(target, *args, **kwargs) -> SearchStrategy:
    return SearchStrategy(lambda rng: target(
        *(_draw_arg(a, rng) for a in args),
        **{k: _draw_arg(v, rng) for k, v in kwargs.items()},
    ))


def recursive(base: SearchStrategy, extend, max_leaves: int = 100) -> SearchStrategy:
    """Bounded-depth stand-in for ``st.recursive``.

    The real strategy grows trees adaptively under a leaf budget; the
    fallback unrolls three extension levels (``extend`` applied to a mix of
    base and already-extended strategies), which covers the nesting the
    suite's grammars exercise while always terminating.
    """
    levels = base
    for _ in range(3):
        levels = one_of(base, extend(levels))
    return levels


def deferred(definition) -> SearchStrategy:
    """Lazily-resolved strategy (self-/forward-references in grammars)."""
    resolved = []

    def draw(rng):
        if not resolved:
            resolved.append(definition())
        return resolved[0].example(rng)

    return SearchStrategy(draw)


def composite(fn):
    """``@st.composite``: ``fn(draw, *args)`` becomes a strategy factory."""

    @functools.wraps(fn)
    def factory(*args, **kwargs):
        def draw_example(rng):
            return fn(lambda s: s.example(rng), *args, **kwargs)

        return SearchStrategy(draw_example)

    return factory


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_kw):
    """Records ``max_examples`` on the (possibly @given-wrapped) test."""

    def deco(fn):
        setattr(fn, _EXAMPLES_ATTR, max_examples)
        return fn

    return deco


def given(*pos_strategies, **kw_strategies):
    """Run the test once per drawn example.

    Keyword strategies bind to same-named parameters; positional strategies
    bind to the test's rightmost parameters (hypothesis semantics).
    """

    def deco(fn):
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        strategies = dict(kw_strategies)
        for name, strat in zip(names[len(names) - len(pos_strategies):],
                               pos_strategies):
            strategies[name] = strat

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, _EXAMPLES_ATTR, DEFAULT_MAX_EXAMPLES)
            for i in range(n):
                rng = random.Random(i)
                drawn = {k: s.example(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example #{i}: {drawn!r}"
                    ) from e

        # hide the drawn parameters from pytest's fixture resolution
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strategies
        ])
        del wrapper.__wrapped__       # keep pytest off the original signature
        return wrapper

    return deco


def install() -> None:
    """Register this module as ``hypothesis`` if the real one is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401  (real package wins)
        return
    except ImportError:
        pass
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.SearchStrategy = SearchStrategy
    st = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "booleans", "sampled_from", "lists",
                 "tuples", "just", "none", "one_of", "builds", "recursive",
                 "deferred", "composite"):
        setattr(st, name, globals()[name])
    st.SearchStrategy = SearchStrategy
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
