"""C-SPARQL frontend tests: golden round-trips, AST equality of the parsed
``.rq`` paper queries against the previous hand-built dataclass builders,
and error reporting for malformed queries.
"""
import pytest

from repro.core import paper_queries as PQ
from repro.core import query as Q
from repro.core.planner import decompose
from repro.core.rdf import Vocab
from repro.core.sparql import (
    SparqlError, parse_query, parse_query_info, serialize_query,
)
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import TweetSchema


@pytest.fixture(scope="module")
def vw():
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(num_artists=8, num_shows=4))
    ts = TweetSchema.create(vocab)
    return vocab, ts, kbd.schema


# --------------------------------------------------------------------------
# the previous hand-built builders, kept verbatim as the AST-equality oracle
# --------------------------------------------------------------------------

def legacy_q15(vocab, ts, kbs):
    return Q.Query(
        name="q15",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("ent"),
                      Q.STREAM),
            Q.FilterSubclass("ent", kbs.rdf_type, kbs.subclass_of,
                             kbs.musical_artist),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"),
                                Q.Const(vocab.pred("out:artistTweet")),
                                Q.Var("ent")),
        ),
    )


def legacy_q16(vocab, ts, kbs):
    return Q.Query(
        name="q16",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("ent"),
                      Q.STREAM),
            Q.PathKB(Q.Var("ent"),
                     (kbs.birth_place, kbs.country, kbs.country_code),
                     Q.Var("cc")),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"), Q.Const(vocab.pred("out:code")),
                                Q.Var("cc")),
        ),
    )


def legacy_cquery1(vocab, ts, kbs):
    return Q.Query(
        name="cquery1",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("artist"),
                      Q.STREAM),
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("show"),
                      Q.STREAM),
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.sentiment_pos), Q.Var("pos"),
                      Q.STREAM),
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.sentiment_neg), Q.Var("neg"),
                      Q.STREAM),
            Q.FilterSubclass("artist", kbs.rdf_type, kbs.subclass_of,
                             kbs.musical_artist),
            Q.FilterSubclass("show", kbs.rdf_type, kbs.subclass_of,
                             kbs.television_show),
            Q.PathKB(Q.Var("artist"),
                     (kbs.birth_place, kbs.country, kbs.country_code),
                     Q.Var("cc")),
            Q.UnionGroup(
                left=(Q.Pattern(Q.Var("tweet"), Q.Const(ts.likes),
                                Q.Var("eng"), Q.STREAM),),
                right=(Q.Pattern(Q.Var("tweet"), Q.Const(ts.shares),
                                 Q.Var("eng"), Q.STREAM),),
            ),
            Q.OptionalGroup(
                patterns=(Q.Pattern(Q.Var("tweet"), Q.Const(ts.shares),
                                    Q.Var("sh"), Q.STREAM),),
            ),
            Q.FilterNum("pos", "ge", Vocab.number(0.0)),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:coMentionedWith")),
                                Q.Var("show")),
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:posSentiment")),
                                Q.Var("pos")),
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:negSentiment")),
                                Q.Var("neg")),
            Q.ConstructTemplate(Q.Var("artist"),
                                Q.Const(vocab.pred("out:countryCode")),
                                Q.Var("cc")),
        ),
    )


LEGACY = {"q15": legacy_q15, "q16": legacy_q16, "cquery1": legacy_cquery1}


# --------------------------------------------------------------------------
# AST equality + round trips
# --------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(LEGACY))
def test_parsed_rq_equals_hand_built_ast(vw, name):
    vocab, ts, kbs = vw
    built = LEGACY[name](vocab, ts, kbs)
    parsed = getattr(PQ, name)(vocab, ts, kbs)
    assert parsed == built


@pytest.mark.parametrize("name", sorted(LEGACY))
def test_round_trip_paper_queries(vw, name):
    """Golden guarantee: parse(serialize(q)) == q."""
    vocab, ts, kbs = vw
    q = getattr(PQ, name)(vocab, ts, kbs)
    text = serialize_query(q, vocab)
    assert parse_query(text, vocab) == q
    # serialization is canonical: a second round trip emits identical text
    assert serialize_query(parse_query(text, vocab), vocab) == text


def test_round_trip_decomposed_subqueries(vw):
    """The serializer is total over planner-generated ASTs (row nodes and
    binding-protocol predicates go through the <dscep:id:N> escape)."""
    vocab, ts, kbs = vw
    q = PQ.cquery1(vocab, ts, kbs)
    dag = decompose(q, vocab)
    for name, sub in dag.subqueries.items():
        text = serialize_query(sub.query, vocab)
        assert parse_query(text, vocab) == sub.query, name


def test_parse_info_carries_registration_and_window(vw):
    vocab, _, _ = vw
    q, info = parse_query_info(PQ.Q15_RQ, vocab)
    assert q.name == "q15" and info.name == "q15"
    assert info.stream_iri == "stream"
    assert info.window_triples == 1000 and info.window_step == 1
    assert info.kb_iris == ("kb",)
    assert dict(info.prefixes)["schema"] == "urn:dscep:schema"


def test_serializer_preserves_known_prefix_iris(vw):
    """Emitted PREFIX declarations document real provenance: well-known
    namespaces get their real IRIs, and IRIs captured at parse time can be
    threaded back through serialize_query."""
    vocab, ts, kbs = vw
    text = serialize_query(PQ.q15(vocab, ts, kbs), vocab)
    assert "PREFIX rdf: <http://www.w3.org/1999/02/22-rdf-syntax-ns#>" in text
    assert "PREFIX dbo: <http://dbpedia.org/ontology/>" in text
    _, info = parse_query_info(PQ.Q15_RQ, vocab)
    q2 = parse_query(PQ.Q15_RQ, vocab)
    custom = serialize_query(q2, vocab, dict(info.prefixes))
    assert "PREFIX schema: <urn:dscep:schema>" in custom


def test_numeric_literals_round_trip_fixed_point(vw):
    vocab, _, _ = vw
    text = """
    REGISTER QUERY numq AS
    PREFIX s: <urn:x>
    CONSTRUCT { ?a s:out ?v . }
    WHERE {
      ?a s:speed ?v .
      FILTER(?v < 19.75)
    }
    """
    q = parse_query(text, vocab)
    flt = [it for it in q.where if isinstance(it, Q.FilterNum)][0]
    assert flt.value_id == Vocab.number(19.75)
    assert parse_query(serialize_query(q, vocab), vocab) == q


def test_negative_literals_round_trip(vw):
    """``FILTER(?v > -5)`` and negative stream-pattern objects (ROADMAP
    frontend next-step): parsed through the NUM_OFFSET fixed-point zero
    point and re-serialized exactly."""
    vocab, _, _ = vw
    text = """
    REGISTER QUERY negq AS
    PREFIX s: <urn:x>
    CONSTRUCT { ?a s:out ?v . }
    WHERE {
      ?a s:speed ?v .
      ?a s:delta -3.25 .
      FILTER(?v > -5 && !(?v <= -19.75))
    }
    """
    q = parse_query(text, vocab)
    pat = [it for it in q.where if isinstance(it, Q.Pattern)][1]
    assert pat.o.id == Vocab.number(-3.25)
    flt = [it for it in q.where if isinstance(it, Q.FilterBool)][0]
    leaves = {(f.op, f.value_id) for f in (flt.args[0], flt.args[1].args[0])}
    assert leaves == {("gt", Vocab.number(-5.0)),
                      ("le", Vocab.number(-19.75))}
    assert Vocab.decode_number(Vocab.number(-5.0)) == -5.0
    assert parse_query(serialize_query(q, vocab), vocab) == q


def test_negative_range_rejected(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?b . }
    FROM STREAM <s> [RANGE TRIPLES -5]
    WHERE { ?a p:x ?b . }
    """, vocab, r"RANGE TRIPLES takes a positive integer")


def test_term_equality_filter_round_trip(vw):
    """``FILTER(?c = dbo:MusicalArtist)`` — term equality on IRI ids
    (second ROADMAP frontend next-step), lowered onto the same FilterNum
    leaf/mask machinery and serialized back as the prefixed name."""
    vocab, _, _ = vw
    text = """
    REGISTER QUERY termq AS
    PREFIX p: <urn:p>
    PREFIX dbo: <http://dbpedia.org/ontology/>
    CONSTRUCT { ?a p:out ?c . }
    WHERE {
      ?a p:type ?c .
      FILTER(?c = dbo:MusicalArtist || ?c != dbo:Band)
    }
    """
    q = parse_query(text, vocab)
    flt = [it for it in q.where if isinstance(it, Q.FilterBool)][0]
    assert flt.args[0] == Q.FilterNum(
        "c", "eq", vocab.term("dbo:MusicalArtist"))
    assert flt.args[1] == Q.FilterNum("c", "ne", vocab.term("dbo:Band"))
    round_trip = serialize_query(q, vocab)
    assert "?c = dbo:MusicalArtist" in round_trip
    assert parse_query(round_trip, vocab) == q


def test_term_ordering_comparison_rejected(vw):
    vocab, _, _ = vw
    vocab.term("dbo:Band")
    _expect_error("""
    PREFIX p: <urn:p>
    PREFIX dbo: <http://dbpedia.org/ontology/>
    CONSTRUCT { ?a p:out ?c . }
    WHERE {
      ?a p:type ?c .
      FILTER(?c >= dbo:Band)
    }
    """, vocab, r"IRIs and strings only support = and !=")


def test_single_hop_path_vs_plain_kb_pattern(vw):
    """`?x (p) ?y` in GRAPH <kb> is a length-1 PathKB; `?x p ?y` is a plain
    KB pattern — both round-trip distinctly."""
    vocab, _, _ = vw
    text = """
    REGISTER QUERY hop AS
    PREFIX m: <urn:m>
    CONSTRUCT { ?a m:out ?b . }
    WHERE {
      ?a m:link ?c .
      GRAPH <kb> {
        ?c (m:hop) ?b .
        ?c m:flat ?d .
      }
    }
    """
    q = parse_query(text, vocab)
    kinds = [type(it).__name__ for it in q.where]
    assert kinds == ["Pattern", "PathKB", "Pattern"]
    assert q.where[1].preds == (vocab.pred("m:hop"),)
    assert q.where[2].src == Q.KB
    assert parse_query(serialize_query(q, vocab), vocab) == q


# --------------------------------------------------------------------------
# error reporting
# --------------------------------------------------------------------------

def _expect_error(text, vocab, match):
    with pytest.raises(SparqlError, match=match):
        parse_query(text, vocab)


def test_unknown_prefix_reports_name_and_position(vw):
    vocab, _, _ = vw
    text = """
    CONSTRUCT { ?a mystery:out ?b . }
    WHERE { ?a mystery:link ?b . }
    """
    with pytest.raises(SparqlError, match=r"unknown prefix 'mystery'") as ei:
        parse_query(text, vocab)
    assert "line" in str(ei.value)


def test_path_longer_than_three_rejected(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?b . }
    WHERE {
      ?a p:x ?m .
      GRAPH <kb> { ?m p:a/p:b/p:c/p:d ?b . }
    }
    """, vocab, r"length 4 exceeds the paper's maximum of 3")


def test_unbound_construct_variable_rejected(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?ghost . }
    WHERE { ?a p:x ?b . }
    """, vocab, r"CONSTRUCT variable \?ghost is not bound")


def test_star_outside_hierarchy_form_rejected(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?b . }
    WHERE {
      ?a p:x ?b .
      GRAPH <kb> { ?a p:one*/p:two ?b . }
    }
    """, vocab, r"path modifiers are only supported as a single-segment")


def test_hierarchy_super_class_must_be_constant(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?b . }
    WHERE {
      ?a p:x ?b .
      GRAPH <kb> { ?a p:type/p:sub* ?b . }
    }
    """, vocab, r"super-class must be a constant")


def test_empty_union_branch_rejected(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?b . }
    WHERE {
      ?a p:x ?b .
      { } UNION { ?a p:y ?b . }
    }
    """, vocab, r"UNION branch is empty")


def test_trailing_garbage_rejected(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?b . }
    WHERE { ?a p:x ?b . }
    bogus
    """, vocab, r"unexpected trailing input")


def test_filter_requires_numeric_comparison(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX p: <urn:p>
    CONSTRUCT { ?a p:out ?b . }
    WHERE {
      ?a p:x ?b .
      FILTER(p:x >= 1.0)
    }
    """, vocab, r"FILTER supports numeric comparisons on a variable")


# --------------------------------------------------------------------------
# Query.variables(): dedupe order (the O(n^2) fix must keep first-seen order)
# --------------------------------------------------------------------------

def test_variables_first_seen_order_and_dedupe(vw):
    vocab, ts, kbs = vw
    q = PQ.cquery1(vocab, ts, kbs)
    vars_ = q.variables()
    assert vars_ == ["tweet", "artist", "show", "pos", "neg", "cc", "eng", "sh"]
    assert len(vars_) == len(set(vars_))


def test_variables_linear_on_wide_machine_generated_query(vw):
    """A parser-scale query (hundreds of patterns) keeps variables() exact:
    every distinct var once, in first-appearance order."""
    vocab, _, _ = vw
    p = vocab.pred("gen:p")
    where = tuple(
        Q.Pattern(Q.Var("s%d" % (i % 97)), Q.Const(p), Q.Var("o%d" % i),
                  Q.STREAM)
        for i in range(600)
    )
    q = Q.Query(name="wide", where=where,
                construct=(Q.ConstructTemplate(Q.Var("s0"), Q.Const(p),
                                               Q.Var("o0")),))
    vars_ = q.variables()
    assert len(vars_) == 97 + 600
    assert vars_[0] == "s0" and vars_[1] == "o0" and vars_[2] == "s1"


# --------------------------------------------------------------------------
# variable-length closure paths, boolean FILTER, SELECT form
# --------------------------------------------------------------------------

def test_closure_path_parse_and_round_trip(vw):
    vocab, _, _ = vw
    text = """
    REGISTER QUERY cp AS
    PREFIX m: <urn:m>
    CONSTRUCT { ?a m:out ?b . }
    WHERE {
      ?a m:link ?c .
      GRAPH <kb> {
        ?c m:hop+ ?b .
        ?b m:near* ?d .
      }
    }
    """
    q = parse_query(text, vocab)
    plus, star = q.where[1], q.where[2]
    assert isinstance(plus, Q.PathClosure) and plus.min_hops == 1
    assert isinstance(star, Q.PathClosure) and star.min_hops == 0
    assert plus.pred == vocab.pred("m:hop")
    assert parse_query(serialize_query(q, vocab), vocab) == q
    text2 = serialize_query(q, vocab)
    assert serialize_query(parse_query(text2, vocab), vocab) == text2


def test_hierarchy_form_still_wins_over_closure(vw):
    """`?x type/subClassOf* Cls` stays a FilterSubclass; the new single-
    segment closure form must not shadow the paper's hierarchy reasoning."""
    vocab, ts, kbs = vw
    q = parse_query(PQ.Q15_RQ, vocab)
    kinds = [type(it).__name__ for it in q.where]
    assert "FilterSubclass" in kinds and "PathClosure" not in kinds


def test_boolean_filter_parse_shapes(vw):
    vocab, _, _ = vw
    text = """
    REGISTER QUERY bf AS
    PREFIX s: <urn:x>
    CONSTRUCT { ?a s:out ?v . }
    WHERE {
      ?a s:speed ?v .
      ?a s:heat ?w .
      FILTER(?v < 19.75 && ?w >= 2.00 || !(?v = 3.00))
    }
    """
    q = parse_query(text, vocab)
    flt = q.where[-1]
    assert isinstance(flt, Q.FilterBool) and flt.op == "or"
    a, b = flt.args
    assert isinstance(a, Q.FilterBool) and a.op == "and" and len(a.args) == 2
    assert isinstance(b, Q.FilterBool) and b.op == "not"
    assert set(flt.vars()) == {"v", "w"}
    assert parse_query(serialize_query(q, vocab), vocab) == q


def test_boolean_filter_nary_and_parens_round_trip(vw):
    """`a && b && c` is one 3-ary node; `(a && b) && c` keeps its nesting."""
    vocab, _, _ = vw
    def parse_filter(body):
        text = ("PREFIX s: <urn:x>\nCONSTRUCT { ?a s:out ?v . }\n"
                "WHERE { ?a s:speed ?v . FILTER(%s) }" % body)
        q = parse_query(text, vocab)
        assert parse_query(serialize_query(q, vocab), vocab) == q
        return q.where[-1]

    flat = parse_filter("?v < 1.00 && ?v < 2.00 && ?v < 3.00")
    assert flat.op == "and" and len(flat.args) == 3
    nested = parse_filter("(?v < 1.00 && ?v < 2.00) && ?v < 3.00")
    assert nested.op == "and" and len(nested.args) == 2
    assert isinstance(nested.args[0], Q.FilterBool)
    assert flat != nested


def test_select_form_lowers_to_binding_templates(vw):
    vocab, _, _ = vw
    text = """
    REGISTER QUERY sel AS
    PREFIX s: <urn:x>
    SELECT ?a ?v
    WHERE { ?a s:speed ?v . }
    """
    q = parse_query(text, vocab)
    assert q.select == ("a", "v")
    assert q.construct == (
        Q.ConstructTemplate(Q.RowId(0), Q.Const(vocab.pred("?:a")),
                            Q.Var("a")),
        Q.ConstructTemplate(Q.RowId(0), Q.Const(vocab.pred("?:v")),
                            Q.Var("v")),
    )
    text2 = serialize_query(q, vocab)
    assert "SELECT ?a ?v" in text2 and "CONSTRUCT" not in text2
    assert parse_query(text2, vocab) == q


def test_select_errors(vw):
    vocab, _, _ = vw
    _expect_error("""
    PREFIX s: <urn:x>
    SELECT ?a ?a
    WHERE { ?a s:speed ?v . }
    """, vocab, r"duplicate SELECT variable")
    _expect_error("""
    PREFIX s: <urn:x>
    SELECT ?ghost
    WHERE { ?a s:speed ?v . }
    """, vocab, r"SELECT variable \?ghost is not bound")


def test_serialize_with_info_round_trips_window_geometry(vw):
    vocab, _, _ = vw
    q, info = parse_query_info(PQ.Q15_RQ, vocab)
    text = serialize_query(q, vocab, dict(info.prefixes), info=info)
    assert "FROM STREAM <stream> [RANGE TRIPLES 1000 STEP 1]" in text
    q2, info2 = parse_query_info(text, vocab)
    assert q2 == q
    assert (info2.stream_iri, info2.window_triples, info2.window_step,
            info2.kb_iris) == ("stream", 1000, 1, ("kb",))


# --------------------------------------------------------------------------
# generative round trips: parse(serialize(q)) == q over the whole grammar
# --------------------------------------------------------------------------

from hypothesis import given, settings  # noqa: E402  (fallback-compatible)

import strategies as gen  # noqa: E402  (tests/ dir is on sys.path)


@settings(max_examples=150, deadline=None, derandomize=True)
@given(q=gen.queries())
def test_generated_ast_round_trips(q):
    vocab = gen.WORLD.vocab
    text = serialize_query(q, vocab)
    assert parse_query(text, vocab) == q
    # canonical: a second round trip emits byte-identical text
    assert serialize_query(parse_query(text, vocab), vocab) == text


@settings(max_examples=50, deadline=None, derandomize=True)
@given(e=gen.filter_exprs)
def test_generated_filter_trees_round_trip(e):
    vocab = gen.WORLD.vocab
    q = Q.Query(
        name="f", where=(
            Q.Pattern(Q.Var("a"), Q.Const(gen.WORLD.stream_preds[0]),
                      Q.Var("x"), Q.STREAM),
            e if isinstance(e, Q.FilterBool) else Q.FilterBool("not", (e,)),
        ),
        construct=(Q.ConstructTemplate(Q.Var("a"),
                                       Q.Const(gen.WORLD.stream_preds[1]),
                                       Q.Var("x")),),
    )
    assert parse_query(serialize_query(q, vocab), vocab) == q
