import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.rdf import make_triples, sort_by_timestamp
from repro.core.window import count_windows, time_windows


def _mk_stream(graph_sizes, ts_start=100):
    rows = []
    for gi, size in enumerate(graph_sizes):
        for k in range(size):
            rows.append((10 + gi, 1, 20 + k, ts_start + gi, gi + 1))
    return sort_by_timestamp(make_triples(rows, capacity=max(1, sum(graph_sizes))))


def test_count_windows_paper_semantics():
    # capacity 5: graphs of sizes 3,2 fill window 0; 4 goes to window 1
    stream = _mk_stream([3, 2, 4])
    w = count_windows(stream, window_capacity=5, max_windows=4)
    counts = np.asarray(w.triples.valid).sum(axis=1)
    assert list(counts) == [5, 4, 0, 0]
    assert list(np.asarray(w.window_valid)) == [True, True, False, False]


def test_count_windows_graph_never_split():
    stream = _mk_stream([2, 2, 2, 2])
    w = count_windows(stream, window_capacity=3, max_windows=4)
    g = np.asarray(w.triples.graph)
    v = np.asarray(w.triples.valid)
    # each graph's rows live in exactly one window
    for graph_id in (1, 2, 3, 4):
        in_window = [(g[i] == graph_id)[v[i]].any() for i in range(4)]
        assert sum(in_window) == 1


def test_count_windows_oversized_graph_truncated():
    stream = _mk_stream([7])
    w = count_windows(stream, window_capacity=4, max_windows=2)
    counts = np.asarray(w.triples.valid).sum(axis=1)
    assert counts[0] == 4 and counts[1] == 0   # bounded buffer, own window


def test_time_windows_tumbling_and_sliding():
    stream = _mk_stream([1, 1, 1, 1])          # ts = 100,101,102,103
    w = time_windows(stream, t0=100, width=2, slide=2, window_capacity=4, max_windows=2)
    counts = np.asarray(w.triples.valid).sum(axis=1)
    assert list(counts) == [2, 2]
    ws = time_windows(stream, t0=100, width=2, slide=1, window_capacity=4, max_windows=3)
    counts = np.asarray(ws.triples.valid).sum(axis=1)
    assert list(counts) == [2, 2, 2]           # overlap duplicates rows


def test_sliding_graph_straddling_slide_boundary_whole_in_every_window():
    # cap 6 STEP 3: graphs of 2 pack one per slide (2+2 > 3), so graph 2
    # lands in slide 1 and is shared by windows 0 and 1 — whole in both
    stream = _mk_stream([2, 2, 2])
    w = count_windows(stream, window_capacity=6, max_windows=4, step=3)
    g = np.asarray(w.triples.graph)
    v = np.asarray(w.triples.valid)
    per_window = [int(((g[i] == 2) & v[i]).sum()) for i in range(4)]
    # appears in >= 2 overlapping windows, and never partially
    assert per_window.count(2) >= 2
    assert all(c in (0, 2) for c in per_window)


def test_sliding_oversized_graph_truncated_to_slide_capacity():
    # a graph bigger than the slide (STEP) truncates to the slide capacity,
    # and every window containing it sees exactly that truncated prefix
    stream = _mk_stream([5, 2])
    w = count_windows(stream, window_capacity=6, max_windows=3, step=3)
    g = np.asarray(w.triples.graph)
    v = np.asarray(w.triples.valid)
    per_window = [int(((g[i] == 1) & v[i]).sum()) for i in range(3)]
    assert all(c in (0, 3) for c in per_window) and 3 in per_window


def test_sliding_empty_slides_invalidate_trailing_windows():
    # one small graph: only windows overlapping its slide are valid; windows
    # made purely of empty slides are invalid and carry zero rows
    stream = _mk_stream([2])
    w = count_windows(stream, window_capacity=6, max_windows=4, step=3)
    counts = np.asarray(w.triples.valid).sum(axis=1)
    assert list(counts) == [2, 0, 0, 0]
    assert list(np.asarray(w.window_valid)) == [True, False, False, False]


@pytest.mark.parametrize("sizes,cap", [([3, 2, 4], 5), ([2, 2, 2, 2], 3),
                                       ([7], 4), ([1, 6, 2, 1], 6)])
def test_step_equals_range_bit_exact_tumbling(sizes, cap):
    # STEP == RANGE is the degenerate 1-slide-per-window geometry; it must
    # reproduce the tumbling arrays bit for bit, not merely set-equal
    stream = _mk_stream(sizes)
    tumble = count_windows(stream, window_capacity=cap, max_windows=4)
    slide = count_windows(stream, window_capacity=cap, max_windows=4, step=cap)
    for ca, cb in zip(tumble.triples, slide.triples):
        assert bool(np.all(np.asarray(ca) == np.asarray(cb)))
    assert bool(np.all(np.asarray(tumble.window_valid)
                       == np.asarray(slide.window_valid)))


def test_time_windows_jaxpr_size_independent_of_max_windows():
    # the batched gather rewrite traces one fixed program: growing
    # max_windows only widens array shapes, it adds no equations
    import jax

    stream = _mk_stream([1, 1, 1, 1])
    small = jax.make_jaxpr(
        lambda s: time_windows(s, 100, 2, 1, 4, 2))(stream)
    big = jax.make_jaxpr(
        lambda s: time_windows(s, 100, 2, 1, 4, 16))(stream)
    assert len(small.jaxpr.eqns) == len(big.jaxpr.eqns)


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=6), min_size=1, max_size=12),
    cap=st.integers(min_value=6, max_value=12),
)
def test_count_windows_properties(sizes, cap):
    """Property: every valid row appears exactly once; no window exceeds cap;
    graphs with size <= cap are never split."""
    stream = _mk_stream(sizes)
    w = count_windows(stream, window_capacity=cap, max_windows=len(sizes) + 1)
    v = np.asarray(w.triples.valid)
    g = np.asarray(w.triples.graph)
    assert v.sum(axis=1).max() <= cap
    placed = {}
    for wi in range(v.shape[0]):
        for graph_id in np.unique(g[wi][v[wi]]):
            placed.setdefault(int(graph_id), set()).add(wi)
    for graph_id, windows_used in placed.items():
        assert len(windows_used) == 1
    total_placed = int(v.sum())
    expected = sum(min(s, cap) for s in sizes)
    assert total_placed == expected
