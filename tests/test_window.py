import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.rdf import make_triples, sort_by_timestamp
from repro.core.window import count_windows, time_windows


def _mk_stream(graph_sizes, ts_start=100):
    rows = []
    for gi, size in enumerate(graph_sizes):
        for k in range(size):
            rows.append((10 + gi, 1, 20 + k, ts_start + gi, gi + 1))
    return sort_by_timestamp(make_triples(rows, capacity=max(1, sum(graph_sizes))))


def test_count_windows_paper_semantics():
    # capacity 5: graphs of sizes 3,2 fill window 0; 4 goes to window 1
    stream = _mk_stream([3, 2, 4])
    w = count_windows(stream, window_capacity=5, max_windows=4)
    counts = np.asarray(w.triples.valid).sum(axis=1)
    assert list(counts) == [5, 4, 0, 0]
    assert list(np.asarray(w.window_valid)) == [True, True, False, False]


def test_count_windows_graph_never_split():
    stream = _mk_stream([2, 2, 2, 2])
    w = count_windows(stream, window_capacity=3, max_windows=4)
    g = np.asarray(w.triples.graph)
    v = np.asarray(w.triples.valid)
    # each graph's rows live in exactly one window
    for graph_id in (1, 2, 3, 4):
        in_window = [(g[i] == graph_id)[v[i]].any() for i in range(4)]
        assert sum(in_window) == 1


def test_count_windows_oversized_graph_truncated():
    stream = _mk_stream([7])
    w = count_windows(stream, window_capacity=4, max_windows=2)
    counts = np.asarray(w.triples.valid).sum(axis=1)
    assert counts[0] == 4 and counts[1] == 0   # bounded buffer, own window


def test_time_windows_tumbling_and_sliding():
    stream = _mk_stream([1, 1, 1, 1])          # ts = 100,101,102,103
    w = time_windows(stream, t0=100, width=2, slide=2, window_capacity=4, max_windows=2)
    counts = np.asarray(w.triples.valid).sum(axis=1)
    assert list(counts) == [2, 2]
    ws = time_windows(stream, t0=100, width=2, slide=1, window_capacity=4, max_windows=3)
    counts = np.asarray(ws.triples.valid).sum(axis=1)
    assert list(counts) == [2, 2, 2]           # overlap duplicates rows


@settings(max_examples=25, deadline=None)
@given(
    sizes=st.lists(st.integers(min_value=1, max_value=6), min_size=1, max_size=12),
    cap=st.integers(min_value=6, max_value=12),
)
def test_count_windows_properties(sizes, cap):
    """Property: every valid row appears exactly once; no window exceeds cap;
    graphs with size <= cap are never split."""
    stream = _mk_stream(sizes)
    w = count_windows(stream, window_capacity=cap, max_windows=len(sizes) + 1)
    v = np.asarray(w.triples.valid)
    g = np.asarray(w.triples.graph)
    assert v.sum(axis=1).max() <= cap
    placed = {}
    for wi in range(v.shape[0]):
        for graph_id in np.unique(g[wi][v[wi]]):
            placed.setdefault(int(graph_id), set()).add(wi)
    for graph_id, windows_used in placed.items():
        assert len(windows_used) == 1
    total_placed = int(v.sum())
    expected = sum(min(s, cap) for s in sizes)
    assert total_placed == expected
