"""Serving substrate: generation consistency, continuous batcher lifecycle,
per-sequence cache lanes, and the serve <-> train parity the engines rely on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke_variant
from repro.models import lm
from repro.serve.batcher import ContinuousBatcher, Request
from repro.serve.engine import generate, greedy_token, make_serve_fns


@pytest.fixture(scope="module")
def tiny():
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_generate_is_deterministic_greedy(tiny):
    cfg, params = tiny
    prompt = jnp.asarray(np.arange(6, dtype=np.int32)[None] % cfg.vocab_size)
    a = np.asarray(generate(params, cfg, prompt, max_new=6))
    b = np.asarray(generate(params, cfg, prompt, max_new=6))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (1, 6)
    assert (a >= 0).all() and (a < cfg.vocab_size).all()


def test_generate_matches_stepwise_forward(tiny):
    """Greedy generation must equal argmax over repeated full forwards."""
    cfg, params = tiny
    prompt = np.asarray([[3, 1, 4, 1, 5]], np.int32)
    gen = np.asarray(generate(params, cfg, jnp.asarray(prompt), max_new=4))
    seq = prompt.copy()
    want = []
    for _ in range(4):
        logits, _ = lm.forward(params, cfg, {"tokens": jnp.asarray(seq)},
                               dropless=True)
        nxt = int(jnp.argmax(logits[0, -1]))
        want.append(nxt)
        seq = np.concatenate([seq, [[nxt]]], axis=1)
    assert gen[0].tolist() == want


def test_batcher_drains_all_requests(tiny):
    cfg, params = tiny
    from repro.launch.serve import make_slot_fns
    slots = 3
    caches = lm.init_cache(cfg, slots, max_len=32, per_seq=True)
    prefill_one, decode_all = make_slot_fns(cfg, 32)
    b = ContinuousBatcher(slots, prefill_one, decode_all)
    rng = np.random.default_rng(1)
    for rid in range(7):
        b.submit(Request(rid=rid,
                         prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                         max_new=4))
    caches, ticks = b.run_until_drained(params, caches)
    assert len(b.completed) == 7
    assert all(len(r.generated) >= 1 for r in b.completed)
    # more requests than slots => at least one slot got reused
    assert ticks >= 4


def test_batcher_slot_isolation(tiny):
    """Two identical prompts in different slots get identical outputs, even
    interleaved with a different prompt: lanes must not leak."""
    cfg, params = tiny
    from repro.launch.serve import make_slot_fns
    slots = 2
    caches = lm.init_cache(cfg, slots, max_len=32, per_seq=True)
    prefill_one, decode_all = make_slot_fns(cfg, 32)
    b = ContinuousBatcher(slots, prefill_one, decode_all)
    same = np.asarray([2, 7, 2, 7], np.int32)
    other = np.asarray([9, 9, 9, 1, 1], np.int32)
    b.submit(Request(rid=0, prompt=same, max_new=5))
    b.submit(Request(rid=1, prompt=other, max_new=5))
    b.submit(Request(rid=2, prompt=same, max_new=5))
    b.run_until_drained(params, caches)
    gen = {r.rid: r.generated for r in b.completed}
    assert gen[0] == gen[2], (gen[0], gen[2])


def test_per_seq_cache_positions_advance_independently(tiny):
    cfg, params = tiny
    from repro.launch.serve import make_slot_fns
    caches = lm.init_cache(cfg, 2, max_len=16, per_seq=True)
    prefill_one, decode_all = make_slot_fns(cfg, 16)
    # prefill slot 0 with 4 tokens, slot 1 with 2 tokens
    t0 = jnp.asarray([[1, 2, 3, 4]], jnp.int32)
    t1 = jnp.asarray([[5, 6]], jnp.int32)
    _, caches = prefill_one(params, t0, caches, 0)
    _, caches = prefill_one(params, t1, caches, 1)
    lens = jax.tree.leaves(
        jax.tree.map(lambda c: c, caches))  # find the len leaves by ndim
    len_leaves = [l for l in jax.tree.leaves(caches) if l.ndim == 2]
    assert len_leaves, "expected per-seq len leaves [period, B]"
    for l in len_leaves:
        np.testing.assert_array_equal(np.asarray(l[0]), [4, 2])
