"""Bounded device ring-buffer channels (repro.core.channel).

Push/pop/overflow semantics under jit with donated state, FIFO ordering
through ring wraparound, and the merge_streams fast paths the channel-fed
runtimes rely on for bit-exact parity.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import channel
from repro.core.rdf import make_triples, sort_by_timestamp
from repro.core.stream import merge_streams


def _payload(x: float):
    """A small pytree payload: vector + scalar leaf."""
    return {"vec": jnp.full((4,), x, jnp.float32),
            "n": jnp.asarray(int(x), jnp.int32)}


def test_push_pop_roundtrip_under_jit():
    ch = channel.make_channel(_payload(0.0), capacity=3)
    assert ch.capacity == 3
    for i in (1, 2):
        ch = channel.push_jit(ch, _payload(float(i)))
    assert int(channel.occupancy(ch)) == 2
    ch, got, ok = channel.pop_jit(ch)
    assert bool(ok) and int(got["n"]) == 1
    assert np.allclose(np.asarray(got["vec"]), 1.0)
    ch, got, ok = channel.pop_jit(ch)
    assert bool(ok) and int(got["n"]) == 2
    assert int(channel.occupancy(ch)) == 0
    assert int(ch.overflows) == 0


def test_overflow_drops_new_payload_and_counts():
    ch = channel.make_channel(_payload(0.0), capacity=2)
    for i in (1, 2, 3, 4):        # 3 and 4 must be dropped, 1 and 2 kept
        ch = channel.push_jit(ch, _payload(float(i)))
    assert int(ch.size) == 2
    assert int(ch.overflows) == 2
    ch, got, ok = channel.pop_jit(ch)
    assert bool(ok) and int(got["n"]) == 1
    ch, got, ok = channel.pop_jit(ch)
    assert bool(ok) and int(got["n"]) == 2


def test_pop_empty_is_invalid_zero_and_state_stable():
    ch = channel.make_channel(_payload(0.0), capacity=2)
    ch, got, ok = channel.pop_jit(ch)
    assert not bool(ok)
    assert int(got["n"]) == 0 and np.allclose(np.asarray(got["vec"]), 0.0)
    assert int(ch.size) == 0 and int(ch.head) == 0
    # push after an empty pop still lands in slot order
    ch = channel.push_jit(ch, _payload(7.0))
    ch, got, ok = channel.pop_jit(ch)
    assert bool(ok) and int(got["n"]) == 7


def test_fifo_through_ring_wraparound():
    ch = channel.make_channel(_payload(0.0), capacity=2)
    seen = []
    nxt = 1
    for _ in range(5):            # 5 push/pop cycles >> capacity: head wraps
        ch = channel.push_jit(ch, _payload(float(nxt)))
        nxt += 1
        ch, got, ok = channel.pop_jit(ch)
        assert bool(ok)
        seen.append(int(got["n"]))
    assert seen == [1, 2, 3, 4, 5]
    assert int(ch.overflows) == 0


def test_wraparound_at_capacity_four_with_interleaved_overflow():
    """Deeper ring (the runtime default): fill to 4, overflow-drop a 5th,
    partially drain, refill across the wrap point, and drain again — FIFO
    order and the drop-new policy must hold through every phase.  Also pins
    the guarded-scatter push: a push into a full ring must leave all four
    stored payloads bit-intact (no slot may be clobbered before the full
    check)."""
    ch = channel.make_channel(_payload(0.0), capacity=4)
    for i in (1, 2, 3, 4):
        ch = channel.push_jit(ch, _payload(float(i)))
    assert int(ch.size) == 4
    ch = channel.push_jit(ch, _payload(99.0))      # full: dropped, counted
    assert int(ch.size) == 4 and int(ch.overflows) == 1
    seen = []
    for _ in range(2):                             # head advances to slot 2
        ch, got, ok = channel.pop_jit(ch)
        assert bool(ok)
        seen.append(int(got["n"]))
    for i in (5, 6):                               # tail wraps to slots 0, 1
        ch = channel.push_jit(ch, _payload(float(i)))
    assert int(ch.size) == 4
    ch = channel.push_jit(ch, _payload(98.0))      # full again post-wrap
    assert int(ch.overflows) == 2
    while int(ch.size):
        ch, got, ok = channel.pop_jit(ch)
        assert bool(ok)
        seen.append(int(got["n"]))
    assert seen == [1, 2, 3, 4, 5, 6], "dropped payloads leaked in or FIFO broke"


def test_push_pop_compose_inside_one_jit_program():
    """An operator step embeds pop+compute+push in one donated program."""

    def step(ch_in, ch_out):
        ch_in, x, ok = channel.pop(ch_in)
        y = jax.tree.map(lambda v: v * 2, x)
        ch_out = channel.push(ch_out, y)
        return ch_in, ch_out

    step_jit = jax.jit(step, donate_argnums=(0, 1))
    ch_a = channel.make_channel(_payload(0.0), capacity=2)
    ch_b = channel.make_channel(_payload(0.0), capacity=2)
    ch_a = channel.push_jit(ch_a, _payload(3.0))
    ch_a, ch_b = step_jit(ch_a, ch_b)
    ch_b, got, ok = channel.pop_jit(ch_b)
    assert bool(ok) and int(got["n"]) == 6
    assert int(ch_a.size) == 0


def test_capacity_must_be_positive():
    with pytest.raises(ValueError):
        channel.make_channel(_payload(0.0), capacity=0)


# --------------------------------------------------------------------------
# merge_streams fast paths (the per-chunk hot path feeding every channel)
# --------------------------------------------------------------------------

def _rows(ts_graph):
    return make_triples(
        [(10 + i, 1, 20 + i, t, g) for i, (t, g) in enumerate(ts_graph)]
    )


def test_merge_single_ordered_input_is_identity():
    chunk = _rows([(1, 1), (2, 2), (2, 2), (5, 3)])
    out = merge_streams([chunk])
    for a, b in zip(out, chunk):
        assert bool(jnp.all(a == b))


def test_merge_single_unordered_input_still_sorts():
    chunk = _rows([(5, 3), (1, 1), (2, 2)])
    out = merge_streams([chunk])
    want = sort_by_timestamp(chunk)
    for a, b in zip(out, want):
        assert bool(jnp.all(a == b))


def test_merge_graph_tie_break_not_skipped():
    """Equal ts but descending graph ids must NOT take the identity path."""
    chunk = _rows([(2, 9), (2, 1)])
    out = merge_streams([chunk])
    want = sort_by_timestamp(chunk)
    for a, b in zip(out, want):
        assert bool(jnp.all(a == b))
    assert int(out.graph[0]) == 1


def test_merge_multi_input_matches_sort_of_concat():
    a = _rows([(1, 1), (4, 2)])
    b = _rows([(2, 3), (3, 4)])
    from repro.core.rdf import concat_triples
    out = merge_streams([a, b])
    want = sort_by_timestamp(concat_triples([a, b]))
    for x, y in zip(out, want):
        assert bool(jnp.all(x == y))


def test_merge_invalid_rows_compact_to_tail():
    chunk = _rows([(3, 1), (1, 2)])
    chunk = chunk._replace(valid=jnp.asarray([True, False]))
    out = merge_streams([chunk])
    assert bool(out.valid[0]) and not bool(out.valid[1])
    assert int(out.ts[0]) == 3
