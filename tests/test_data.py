"""Data layer: deterministic resumable token pipeline, tweet-stream and
KB-generator structural properties (hypothesis where it matters)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.rdf import NUM_BASE, Vocab
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tokens import TokenDatasetConfig, batch_at_step, token_stream
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)


# --------------------------------------------------------------------------
# token pipeline (training data substrate)
# --------------------------------------------------------------------------

def test_batches_deterministic_per_step():
    cfg = TokenDatasetConfig(vocab_size=1000, seq_len=16, global_batch=4)
    a = batch_at_step(cfg, 7)
    b = batch_at_step(cfg, 7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_batches_differ_across_steps_and_hosts():
    cfg = TokenDatasetConfig(vocab_size=1000, seq_len=16, global_batch=4)
    a = batch_at_step(cfg, 1)["tokens"]
    b = batch_at_step(cfg, 2)["tokens"]
    assert not np.array_equal(a, b)
    cfg2 = TokenDatasetConfig(vocab_size=1000, seq_len=16, global_batch=4,
                              num_hosts=2, host_id=1)
    c = batch_at_step(cfg2, 1)["tokens"]
    assert not np.array_equal(a[: c.shape[0]], c)


def test_stream_resume_no_skip_no_dup():
    """Restart from step k sees exactly the batches the failed run would."""
    cfg = TokenDatasetConfig(vocab_size=500, seq_len=8, global_batch=2)
    full = [b["tokens"] for _, b in zip(range(6), token_stream(cfg))]
    resumed = [b["tokens"] for _, b in zip(range(3), token_stream(cfg, start_step=3))]
    for i in range(3):
        np.testing.assert_array_equal(full[3 + i], resumed[i])


def test_labels_are_shifted_tokens():
    cfg = TokenDatasetConfig(vocab_size=100, seq_len=8, global_batch=2)
    b = batch_at_step(cfg, 0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# --------------------------------------------------------------------------
# tweet stream / KB generators (the DSCEP evaluation substrate)
# --------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 40), cap=st.integers(16, 128), seed=st.integers(0, 99))
def test_stream_chunks_never_split_graph_events(n, cap, seed):
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(num_artists=8, num_shows=4, seed=seed))
    ts = TweetSchema.create(vocab)
    rows = generate_tweets(vocab, ts, kbd.artist_ids,
                           TweetStreamConfig(num_tweets=n, seed=seed))
    seen = {}
    for ci, chunk in enumerate(stream_chunks(rows, cap)):
        g = np.asarray(chunk.graph)[np.asarray(chunk.valid)]
        for gid in set(g.tolist()):
            assert seen.setdefault(gid, ci) == ci, \
                f"graph {gid} split across chunks"


def test_tweet_timestamps_monotone():
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(num_artists=8, num_shows=4))
    ts = TweetSchema.create(vocab)
    rows = generate_tweets(vocab, ts, kbd.artist_ids,
                           TweetStreamConfig(num_tweets=25))
    stamps = [r[3] for r in rows]
    assert stamps == sorted(stamps)   # paper assumption 3


def test_kb_filler_is_disjoint_from_used_predicates():
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(num_artists=8, num_shows=4,
                                      filler_triples=100))
    filler_pred = vocab.pred("filler:pred")
    used_preds = {kbd.schema.rdf_type, kbd.schema.subclass_of,
                  kbd.schema.birth_place, kbd.schema.country,
                  kbd.schema.country_code}
    assert filler_pred not in used_preds
    rows = np.asarray(kbd.rows, np.uint32)
    assert (rows[:, 1] == filler_pred).sum() == 100


def test_numeric_literals_order_isomorphic():
    vals = [0.0, 0.5, 1.25, 3.14, 100.0]
    ids = [Vocab.number(v) for v in vals]
    assert ids == sorted(ids)
    assert all(i >= int(NUM_BASE) for i in ids)
    assert Vocab.decode_number(Vocab.number(2.37)) == pytest.approx(2.37)
