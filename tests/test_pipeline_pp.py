"""Pipeline parallelism: GPipe rolling schedule ≡ sequential stage stack,
schedule accounting, and gradient flow through the pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train.pipeline_pp import (
    PipelineConfig, pipeline_apply, pipeline_stats, sequential_reference,
    stack_stages,
)


def _mlp_stage(p, x):
    return jnp.tanh(x @ p["w"] + p["b"])


def _make_params(key, S, d):
    ks = jax.random.split(key, S)
    return stack_stages([
        {"w": jax.random.normal(k, (d, d)) * 0.3, "b": jnp.zeros((d,))}
        for k in ks
    ])


@pytest.mark.parametrize("S,M", [(1, 3), (2, 4), (4, 4), (4, 9), (8, 2)])
def test_pipeline_matches_sequential(S, M):
    d, mb = 8, 4
    params = _make_params(jax.random.PRNGKey(0), S, d)
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    got = pipeline_apply(_mlp_stage, params, x, PipelineConfig(S))
    want = sequential_reference(_mlp_stage, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(S=st.integers(1, 6), M=st.integers(1, 8), seed=st.integers(0, 50))
def test_pipeline_property_random(S, M, seed):
    d, mb = 4, 2
    params = _make_params(jax.random.PRNGKey(seed), S, d)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (M, mb, d))
    got = pipeline_apply(_mlp_stage, params, x, PipelineConfig(S))
    want = sequential_reference(_mlp_stage, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_schedule_accounting():
    s = pipeline_stats(num_stages=4, num_microbatches=12)
    assert s["ticks"] == 15
    assert s["bubble_fraction"] == pytest.approx(3 / 15)
    assert s["utilization"] == pytest.approx(12 / 15)
    # more microbatches -> smaller bubble (the GPipe scaling law)
    assert (pipeline_stats(4, 48)["bubble_fraction"]
            < pipeline_stats(4, 12)["bubble_fraction"])


def test_gradients_flow_through_pipeline():
    """PP must be trainable: grads through the rolled schedule match grads
    through the sequential reference."""
    S, M, d, mb = 3, 4, 4, 2
    params = _make_params(jax.random.PRNGKey(2), S, d)
    x = jax.random.normal(jax.random.PRNGKey(3), (M, mb, d))

    def loss_pp(p):
        return jnp.sum(pipeline_apply(_mlp_stage, p, x, PipelineConfig(S)) ** 2)

    def loss_seq(p):
        return jnp.sum(sequential_reference(_mlp_stage, p, x) ** 2)

    g_pp = jax.grad(loss_pp)(params)
    g_seq = jax.grad(loss_seq)(params)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5), g_pp, g_seq)


def test_pipeline_jits_and_shards_on_host_mesh():
    """Under a mesh, stage-axis pinning compiles (collective-permute path)."""
    S, M, d, mb = 2, 4, 4, 2
    params = _make_params(jax.random.PRNGKey(4), S, d)
    x = jax.random.normal(jax.random.PRNGKey(5), (M, mb, d))
    mesh = jax.make_mesh((jax.device_count(),), ("stage",))
    cfg = PipelineConfig(S, stage_axis="stage")
    with mesh:
        got = jax.jit(
            lambda p, x: pipeline_apply(_mlp_stage, p, x, cfg))(params, x)
    want = sequential_reference(_mlp_stage, params, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
