"""Decode-attention kernel: fidelity vs the jnp oracle across shapes/dtypes,
per-sequence length semantics, and equivalence with masked full attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.decode_attention import ops as da_ops
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ref import attention_ref


def _mk(b, hq, hk, s, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, 1, d), dtype)
    k = jax.random.normal(ks[1], (b, hk, s, d), dtype)
    v = jax.random.normal(ks[2], (b, hk, s, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,hq,hk,s,d", [
    (1, 4, 4, 64, 32), (2, 8, 2, 256, 64), (3, 4, 1, 128, 64),
    (2, 2, 2, 512, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_matches_ref(b, hq, hk, s, d, dtype):
    q, k, v = _mk(b, hq, hk, s, d, dtype, seed=s)
    lengths = jnp.asarray(
        np.random.default_rng(s).integers(1, s + 1, size=b), jnp.int32)
    got = da_ops.decode_attention(q, k, v, lengths, bk=64)
    want = decode_attention_ref(q, k, v, lengths)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_non_block_aligned_cache_is_padded():
    q, k, v = _mk(2, 4, 2, 100, 32, jnp.float32, seed=1)   # 100 % 64 != 0
    lengths = jnp.asarray([100, 37], jnp.int32)
    got = da_ops.decode_attention(q, k, v, lengths, bk=64)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_zero_length_sequence_outputs_zeros():
    q, k, v = _mk(2, 2, 2, 64, 32, jnp.float32, seed=2)
    lengths = jnp.asarray([0, 64], jnp.int32)
    got = da_ops.decode_attention(q, k, v, lengths, bk=32)
    assert bool(jnp.all(got[0] == 0.0))
    assert bool(jnp.any(got[1] != 0.0))


def test_matches_causal_full_attention_last_row():
    """Decode at position L-1 == last row of causal full attention over L."""
    b, hq, hk, L, d = 2, 4, 2, 96, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    k = jax.random.normal(ks[0], (b, hk, L, d), jnp.float32)
    v = jax.random.normal(ks[1], (b, hk, L, d), jnp.float32)
    qfull = jax.random.normal(ks[2], (b, hq, L, d), jnp.float32)
    full = attention_ref(qfull, k, v, causal=True, window=None)
    got = da_ops.decode_attention(qfull[:, :, -1:], k, v,
                                  jnp.full((b,), L, jnp.int32), bk=32)
    np.testing.assert_allclose(np.asarray(got[:, :, 0]),
                               np.asarray(full[:, :, -1]),
                               rtol=2e-5, atol=2e-5)


def test_model_decode_pallas_path_matches_xla():
    """End-to-end: lm.decode_step with impl='pallas' routes single-token
    decode through this kernel and must match the jnp (xla) path."""
    from repro.configs import get_config, smoke_variant
    from repro.models import lm

    cfg = smoke_variant(get_config("qwen2-1.5b"))
    params, _ = lm.init_model(jax.random.PRNGKey(7), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(8), (2, 1), 0, cfg.vocab_size)
    cx = lm.init_cache(cfg, 2, 32)
    cp = lm.init_cache(cfg, 2, 32)
    # pre-fill a few positions so lengths differ from zero
    warm = jax.random.randint(jax.random.PRNGKey(9), (2, 4), 0, cfg.vocab_size)
    _, cx = lm.decode_step(params, cfg, {"tokens": warm}, cx, jnp.int32(0))
    _, cp = lm.decode_step(params, cfg, {"tokens": warm}, cp, jnp.int32(0))
    lx, _ = lm.decode_step(params, cfg, {"tokens": toks}, cx, jnp.int32(4),
                           impl="xla")
    lp, _ = lm.decode_step(params, cfg, {"tokens": toks}, cp, jnp.int32(4),
                           impl="pallas")
    np.testing.assert_allclose(np.asarray(lx, np.float32),
                               np.asarray(lp, np.float32),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(
    b=st.integers(1, 3), group=st.integers(1, 4),
    s=st.sampled_from([32, 64, 96]), seed=st.integers(0, 100),
)
def test_decode_property_random(b, group, s, seed):
    hk, d = 2, 32
    q, k, v = _mk(b, hk * group, hk, s, d, jnp.float32, seed=seed)
    lengths = jnp.asarray(
        np.random.default_rng(seed).integers(0, s + 1, size=b), jnp.int32)
    got = da_ops.decode_attention(q, k, v, lengths, bk=32)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
