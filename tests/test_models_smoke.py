"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and finiteness; decode-vs-
forward consistency for every cache family (GQA, MLA, SWA, SSD, Mamba-1
hybrid, codebooks)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke_variant
from repro.models import lm
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_loop import TrainConfig, make_train_step


def _batch(cfg, key, b=2, t=16):
    shape = (b, t, cfg.num_codebooks) if cfg.num_codebooks else (b, t)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    return {"tokens": tokens, "labels": tokens}


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(0)
    params, spec = lm.init_model(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = lm.forward(params, cfg, batch)
    b, t = batch["tokens"].shape[:2]
    if cfg.num_codebooks:
        assert logits.shape == (b, t, cfg.num_codebooks, cfg.padded_vocab)
    else:
        assert logits.shape == (b, t, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(1)
    params, _ = lm.init_model(key, cfg)
    tcfg = TrainConfig(opt=AdamWConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10),
                       remat="none")
    step = jax.jit(make_train_step(cfg, tcfg))
    opt_state = init_opt_state(params)
    batch = _batch(cfg, key)
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # a parameter actually moved
    moved = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                                        - b.astype(jnp.float32)))),
                     params, params2)
    )
    assert max(moved) > 0


@pytest.mark.parametrize(
    "arch",
    ["qwen2-1.5b", "minicpm3-4b", "mixtral-8x22b", "mamba2-130m",
     "jamba-v0.1-52b", "musicgen-large", "h2o-danube-1.8b"],
)
def test_decode_matches_forward(arch):
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(2)
    params, _ = lm.init_model(key, cfg)
    B, T = 1, 8
    shape = (B, T, cfg.num_codebooks) if cfg.num_codebooks else (B, T)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab_size)
    # serve reference: dropless MoE, matching the always-dropless decode path
    full_logits, _ = lm.forward(params, cfg, {"tokens": tokens}, dropless=True)
    caches = lm.init_cache(cfg, B, max_len=16)
    outs = []
    for t in range(T):
        logits_t, caches = lm.decode_step(
            params, cfg, {"tokens": tokens[:, t:t + 1]}, caches, jnp.int32(t))
        outs.append(logits_t)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-4, atol=2e-4,
    )


def test_prefill_then_decode_matches_forward():
    """Chunked prefill (T>1 with cache) must agree with the full forward."""
    cfg = smoke_variant(get_config("jamba-v0.1-52b"))
    key = jax.random.PRNGKey(3)
    params, _ = lm.init_model(key, cfg)
    B, T = 1, 12
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, {"tokens": tokens}, dropless=True)
    caches = lm.init_cache(cfg, B, max_len=16)
    # prefill first 8, then decode 4 singles
    logits_p, caches = lm.decode_step(
        params, cfg, {"tokens": tokens[:, :8]}, caches, jnp.int32(0))
    outs = [logits_p]
    for t in range(8, T):
        lt, caches = lm.decode_step(
            params, cfg, {"tokens": tokens[:, t:t + 1]}, caches, jnp.int32(t))
        outs.append(lt)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-4, atol=2e-4,
    )


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "deepseek-v2-236b"])
def test_moe_hierarchical_dispatch_exact_when_dropless(arch):
    """Per-group (hierarchical) MoE dispatch ≡ global dispatch when dropless
    — the §Perf lever that keeps sort/gather/scatter device-local."""
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(6)
    params, _ = lm.init_model(key, cfg)
    tokens = jax.random.randint(key, (4, 8), 0, cfg.vocab_size)
    base, _ = lm.forward(params, cfg, {"tokens": tokens}, dropless=True)
    for g in (2, 4):
        got, _ = lm.forward(params, cfg, {"tokens": tokens}, dropless=True,
                            moe_groups=g)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(base, np.float32),
            rtol=2e-4, atol=2e-4,
        )


@pytest.mark.parametrize("arch", ["minicpm3-4b", "deepseek-v2-236b"])
def test_mla_absorbed_decode_matches_naive(arch):
    """Latent-space (absorbed) MLA decode ≡ naive expand-then-attend decode
    ≡ the dropless full forward — the §Perf optimization must be exact."""
    import dataclasses as dc
    cfg = smoke_variant(get_config(arch))
    key = jax.random.PRNGKey(5)
    params, _ = lm.init_model(key, cfg)
    B, T = 2, 10
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    full_logits, _ = lm.forward(params, cfg, {"tokens": tokens}, dropless=True)

    cfg_abs = dc.replace(cfg, mla_absorbed=True)
    caches = lm.init_cache(cfg_abs, B, max_len=16)
    # chunked prefill (6) then decode singles — both cache paths exercised
    lp, caches = lm.decode_step(params, cfg_abs, {"tokens": tokens[:, :6]},
                                caches, jnp.int32(0))
    outs = [lp]
    for t in range(6, T):
        lt, caches = lm.decode_step(params, cfg_abs,
                                    {"tokens": tokens[:, t:t + 1]},
                                    caches, jnp.int32(t))
        outs.append(lt)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32), np.asarray(full_logits, np.float32),
        rtol=2e-4, atol=2e-4,
    )


def test_vlm_embeds_input_and_mrope_positions():
    cfg = smoke_variant(get_config("qwen2-vl-7b"))
    key = jax.random.PRNGKey(4)
    params, _ = lm.init_model(key, cfg)
    B, T = 2, 8
    embeds = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    positions = jnp.broadcast_to(jnp.arange(T)[None, None], (3, B, T))
    logits, _ = lm.forward(params, cfg, {"embeds": embeds, "positions": positions})
    assert logits.shape == (B, T, cfg.padded_vocab)
    # RoPE is shift-equivariant: a UNIFORM shift of one position stream must
    # NOT change the logits (relative geometry unchanged) ...
    pos_shift = positions.at[1].add(5)
    logits_s, _ = lm.forward(params, cfg,
                             {"embeds": embeds, "positions": pos_shift})
    assert float(jnp.max(jnp.abs(logits - logits_s))) < 1e-4
    # ... while a NON-uniform change of the same stream (different spatial
    # layout) must change them — M-RoPE really consumes the 3D positions
    pos2 = positions.at[1, :, T // 2:].add(5)
    logits2, _ = lm.forward(params, cfg, {"embeds": embeds, "positions": pos2})
    assert float(jnp.max(jnp.abs(logits - logits2))) > 1e-4


def test_param_counts_sane():
    """Full configs: reported totals are in the right ballpark."""
    expected = {
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "olmo-1b": (0.9e9, 1.6e9),
        "mamba2-130m": (0.09e9, 0.2e9),
        "mixtral-8x22b": (1.2e11, 1.6e11),
        "deepseek-v2-236b": (2.0e11, 2.8e11),
        "jamba-v0.1-52b": (4.2e10, 6.5e10),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).param_counts()["total"]
        assert lo < n < hi, (arch, n)


def test_moe_active_params_smaller():
    for arch in ["mixtral-8x22b", "deepseek-v2-236b", "jamba-v0.1-52b"]:
        counts = get_config(arch).param_counts()
        assert counts["active"] < 0.55 * counts["total"], (arch, counts)
