import numpy as np
import pytest

from repro.core.kb import (
    build_kb, gather_matches, host_rows, kb_from_triples, pad_to, probe_range,
    prune, shard_rows,
)
from repro.core.rdf import Vocab, composite_key


@pytest.fixture
def small_kb():
    v = Vocab()
    p_type = v.pred("rdf:type")
    p_bp = v.pred("dbo:birthPlace")
    a, b, c = v.term("a"), v.term("b"), v.term("c")
    x, y = v.term("x"), v.term("y")
    rows = [(a, p_type, x), (b, p_type, x), (b, p_type, y), (c, p_bp, y)]
    return v, p_type, p_bp, (a, b, c, x, y), kb_from_triples(rows, capacity=8)


def test_build_and_count(small_kb):
    *_, kb = small_kb
    assert int(kb.count()) == 4
    assert kb.capacity == 8


def test_probe_finds_exact_rows(small_kb):
    v, p_type, p_bp, (a, b, c, x, y), kb = small_kb
    key = composite_key(p_type, b)
    lo, hi = probe_range(kb.key_ps, key)
    assert int(hi - lo) == 2                     # b has two type rows
    (ms, mp, mo), ok, ovf = gather_matches((kb.s_ps, kb.p_ps, kb.o_ps), lo, hi, 4)
    got = sorted(int(o) for o, k in zip(np.asarray(mo), np.asarray(ok)) if k)
    assert got == sorted([x, y])
    assert not bool(ovf)


def test_probe_po_view(small_kb):
    v, p_type, p_bp, (a, b, c, x, y), kb = small_kb
    key = composite_key(p_type, x)
    lo, hi = probe_range(kb.key_po, key)
    (ms, mp, mo), ok, _ = gather_matches((kb.s_po, kb.p_po, kb.o_po), lo, hi, 4)
    got = sorted(int(s) for s, k in zip(np.asarray(ms), np.asarray(ok)) if k)
    assert got == sorted([a, b])


def test_prune_by_predicate_and_object(small_kb):
    v, p_type, p_bp, (a, b, c, x, y), kb = small_kb
    used = prune(kb, predicates=[p_type])
    assert int(used.count()) == 3
    narrowed = prune(kb, predicates=[p_type], objects_by_pred={p_type: {x}})
    assert int(narrowed.count()) == 2            # only type->x rows


def test_pad_and_shard(small_kb):
    *_, kb = small_kb
    padded = pad_to(kb, 16)
    assert padded.capacity == 16 and int(padded.count()) == 4
    sharded = shard_rows(padded, 4)
    assert sharded.key_ps.shape == (4, 4)
    # shards partition the sorted key space: concatenation reproduces the sort
    keys = np.asarray(sharded.key_ps).reshape(-1)
    assert (np.diff(keys.astype(np.int64)) >= 0).all()


def test_host_rows_roundtrip(small_kb):
    *_, kb = small_kb
    rows = host_rows(kb)
    assert rows.shape == (4, 3)
