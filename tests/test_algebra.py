import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import algebra
from repro.core.kb import kb_from_triples
from repro.core.pattern import Bindings, CompiledPattern, Slot, empty_bindings
from repro.core.rdf import PAD_ID, Vocab, make_triples

V = Vocab()
P1 = V.pred("p1")
P2 = V.pred("p2")
A, B, C, D, E = (V.term(t) for t in "abcde")


def mk_bindings(rows, num_vars, cap=None):
    cap = cap or max(len(rows), 1)
    cols = np.zeros((cap, num_vars), np.uint32)
    valid = np.zeros((cap,), bool)
    for i, r in enumerate(rows):
        cols[i] = r
        valid[i] = True
    return Bindings(jnp.asarray(cols), jnp.asarray(valid), jnp.zeros((), bool))


def rows_of(b: Bindings):
    cols, valid = np.asarray(b.cols), np.asarray(b.valid)
    return sorted(tuple(int(x) for x in cols[i]) for i in range(len(valid)) if valid[i])


# --------------------------------------------------------------------------
def test_scan_pattern_consts_and_vars():
    w = make_triples([(A, P1, B, 5, 1), (C, P1, D, 5, 1), (A, P2, E, 6, 2)], capacity=8)
    pat = CompiledPattern(Slot.free(0), Slot.const_(P1), Slot.free(1))
    out = algebra.scan_pattern(w, pat, num_vars=2, out_cap=4)
    assert rows_of(out) == sorted([(A, B), (C, D)])


def test_scan_pattern_repeated_var():
    w = make_triples([(A, P1, A, 0, 1), (A, P1, B, 0, 1)], capacity=4)
    pat = CompiledPattern(Slot.free(0), Slot.const_(P1), Slot.free(0))
    out = algebra.scan_pattern(w, pat, num_vars=1, out_cap=4)
    assert rows_of(out) == [(A,)]


def test_join_natural():
    a = mk_bindings([(A, B, 0), (C, D, 0)], 3)
    b = mk_bindings([(A, 0, E), (A, 0, D)], 3)
    out = algebra.join(a, b, shared=(0,), out_cap=8)
    assert rows_of(out) == sorted([(A, B, E), (A, B, D)])


def test_join_overflow_flag():
    a = mk_bindings([(A, 0)], 2)
    b = mk_bindings([(A, B), (A, C), (A, D)], 2)
    out = algebra.join(a, b, shared=(0,), out_cap=2)
    assert bool(out.overflow)
    assert int(out.count()) == 2                    # prefix-preserving clip


def test_union_and_optional():
    a = mk_bindings([(A, B)], 2, cap=4)
    b = mk_bindings([(C, D)], 2, cap=4)
    u = algebra.union(a, b, out_cap=4)
    assert rows_of(u) == sorted([(A, B), (C, D)])

    left = mk_bindings([(A, 0), (C, 0)], 2, cap=4)
    right = mk_bindings([(A, B)], 2, cap=4)
    o = algebra.optional_join(left, right, shared=(0,), out_cap=8)
    assert rows_of(o) == sorted([(A, B), (C, 0)])   # unmatched keeps PAD


def test_filters():
    n1, n2 = Vocab.number(1.0), Vocab.number(3.0)
    b = mk_bindings([(A, n1), (B, n2)], 2)
    lo = algebra.filter_num(b, var=1, op="lt", value_id=Vocab.number(2.0))
    assert rows_of(lo) == [(A, n1)]
    member = algebra.filter_in(b, var=0, sorted_ids=jnp.asarray(sorted([B, D]), jnp.uint32))
    assert rows_of(member) == [(B, n2)]
    nb = mk_bindings([(A, 0)], 2)
    assert rows_of(algebra.filter_bound(nb, 1)) == []


def test_filter_negative_literals_order_isomorphic():
    n_neg, n_pos = Vocab.number(-5.0), Vocab.number(2.0)
    assert n_neg < Vocab.number(-4.99) < Vocab.number(0.0) < n_pos
    b = mk_bindings([(A, n_neg), (B, n_pos)], 2)
    gt = algebra.filter_num(b, var=1, op="gt", value_id=Vocab.number(-10.0))
    assert rows_of(gt) == sorted([(A, n_neg), (B, n_pos)])
    lt = algebra.filter_num(b, var=1, op="lt", value_id=Vocab.number(0.0))
    assert rows_of(lt) == [(A, n_neg)]


def test_filter_term_equality():
    """=/!= on IRI/string ids: exact id equality, unbound is an error
    (dropped for both operators), numerics are just different terms."""
    n1 = Vocab.number(1.0)
    b = mk_bindings([(A, B), (C, D), (A, 0), (A, n1)], 2, cap=8)
    eq = algebra.filter_num(b, var=1, op="eq", value_id=B)
    assert rows_of(eq) == [(A, B)]
    ne = algebra.filter_num(b, var=1, op="ne", value_id=B)
    assert rows_of(ne) == sorted([(C, D), (A, n1)])   # unbound row dropped


def test_project_and_distinct():
    b = mk_bindings([(A, B), (A, C), (A, B)], 2, cap=4)
    p = algebra.project(b, keep=(0,))
    assert rows_of(p) == [(A, 0)] * 3
    d = algebra.distinct(p)
    assert rows_of(d) == [(A, 0)]
    d2 = algebra.distinct(b)
    assert rows_of(d2) == sorted([(A, B), (A, C)])


# --------------------------------------------------------------------------
KB_ROWS = [(A, P1, B), (A, P1, C), (B, P1, C), (C, P2, D), (B, P2, D)]
KB = kb_from_triples(KB_ROWS, capacity=16)


def brute_kb_join(bind_rows, pat_modes, num_vars):
    """Python oracle for kb_join: pat_modes = ((mode, val), ...) per slot."""
    out = []
    for row in bind_rows:
        for (s, p, o) in KB_ROWS:
            trip = (s, p, o)
            new = list(row)
            ok = True
            for slot_i, (mode, val) in enumerate(pat_modes):
                tv = trip[slot_i]
                if mode == "const":
                    ok &= tv == val
                elif mode == "bound":
                    ok &= tv == row[val]
                else:
                    pass
            if ok:
                for slot_i, (mode, val) in enumerate(pat_modes):
                    if mode == "free":
                        new[val] = trip[slot_i]
                out.append(tuple(new))
    return sorted(out)


@pytest.mark.parametrize("method", ["scan", "probe"])
def test_kb_join_methods_match_oracle(method):
    bind = mk_bindings([(A, 0), (B, 0), (E, 0)], 2, cap=4)
    pat = CompiledPattern(Slot.bound(0), Slot.const_(P1), Slot.free(1))
    out = algebra.kb_join(bind, KB, pat, out_cap=16, method=method)
    oracle = brute_kb_join([(A, 0), (B, 0), (E, 0)],
                           (("bound", 0), ("const", P1), ("free", 1)), 2)
    assert rows_of(out) == oracle


def test_kb_join_probe_po_view():
    bind = mk_bindings([(0, C)], 2, cap=2)
    pat = CompiledPattern(Slot.free(0), Slot.const_(P1), Slot.bound(1))
    out = algebra.kb_join(bind, KB, pat, out_cap=8, method="probe")
    oracle = brute_kb_join([(0, C)], (("free", 0), ("const", P1), ("bound", 1)), 2)
    assert rows_of(out) == oracle


def test_kb_join_probe_overflow():
    rows = [(A, P1, V.term("o%d" % i)) for i in range(12)]
    kb = kb_from_triples(rows, capacity=16)
    bind = mk_bindings([(A, 0)], 2, cap=2)
    pat = CompiledPattern(Slot.bound(0), Slot.const_(P1), Slot.free(1))
    out = algebra.kb_join_probe(bind, kb, pat, out_cap=32, k_max=8)
    assert bool(out.overflow)                   # 12 matches > k_max=8
    assert int(out.count()) == 8


def test_construct_emits_graph_events():
    bind = mk_bindings([(A, B), (C, D)], 2, cap=4)
    out, ovf = algebra.construct(
        bind,
        templates=((("var", 0), ("const", P2), ("var", 1)),
                   (("var", 0), ("const", P1), ("const", E))),
        ts=jnp.uint32(42), out_cap=8,
    )
    assert not bool(ovf)
    v = np.asarray(out.valid)
    assert v.sum() == 4
    assert set(np.asarray(out.ts)[v]) == {42}
    # two triples per binding row share a graph id
    g = np.asarray(out.graph)[v]
    assert len(np.unique(g)) == 2


# --------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(
    a_rows=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), max_size=6),
    b_rows=st.lists(st.tuples(st.integers(1, 5), st.integers(1, 5)), max_size=6),
)
def test_join_matches_bruteforce(a_rows, b_rows):
    """Property: natural join == nested-loop python join (shared col 0)."""
    base = V.term("base")
    a_rows = [(base + x, base + y) for x, y in a_rows]
    b_rows = [(base + x, base + 100 + y) for x, y in b_rows]
    a = mk_bindings([(s, v, 0) for s, v in a_rows], 3, cap=8)
    b = mk_bindings([(s, 0, w) for s, w in b_rows], 3, cap=8)
    out = algebra.join(a, b, shared=(0,), out_cap=64)
    brute = sorted(
        (s1, v, w) for (s1, v) in a_rows for (s2, w) in b_rows if s1 == s2
    )
    assert rows_of(out) == brute
