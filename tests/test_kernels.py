"""Per-kernel fidelity: Pallas (interpret mode) vs pure-jnp oracle, swept
over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kb import kb_from_triples
from repro.core.pattern import Bindings, CompiledPattern, Slot
from repro.core.rdf import Vocab

from repro.kernels.hash_join import ops as hj_ops
from repro.kernels.hash_join.ref import match_matrix_ref
from repro.kernels.closure import ops as cl_ops
from repro.kernels.closure.ref import closure_ref
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd.ref import ssd_ref


# --------------------------------------------------------------------------
# hash_join
# --------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,nv", [(16, 64, 2), (64, 256, 3), (128, 512, 4)])
@pytest.mark.parametrize("pat_kind", ["bound_const_free", "free_const_bound", "const_bound_free"])
def test_hash_join_matches_ref(m, n, nv, pat_kind):
    rng = np.random.default_rng(m * n + len(pat_kind))
    base = 5000
    cols = rng.integers(base, base + 30, size=(m, nv)).astype(np.uint32)
    bvalid = rng.random(m) < 0.9
    rows = [
        (int(rng.integers(base, base + 30)), int(rng.integers(1, 4)),
         int(rng.integers(base, base + 30)))
        for _ in range(n - 4)
    ]
    kb = kb_from_triples(rows, capacity=n)
    if pat_kind == "bound_const_free":
        pat = CompiledPattern(Slot.bound(0), Slot.const_(2), Slot.free(1))
    elif pat_kind == "free_const_bound":
        pat = CompiledPattern(Slot.free(0), Slot.const_(1), Slot.bound(1))
    else:
        pat = CompiledPattern(Slot.const_(base + 3), Slot.bound(0), Slot.free(1))

    bind = Bindings(jnp.asarray(cols), jnp.asarray(bvalid), jnp.zeros((), bool))
    got = hj_ops.match_matrix(bind, kb, pat)
    want = match_matrix_ref(
        bind.cols, bind.valid, kb.s_ps, kb.p_ps, kb.o_ps, kb.valid, pat
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_hash_join_repeated_var():
    rng = np.random.default_rng(0)
    rows = [(10_000 + i % 3, 1, 10_000 + i % 2) for i in range(12)]
    kb = kb_from_triples(rows, capacity=16)
    cols = rng.integers(9_999, 10_004, size=(8, 2)).astype(np.uint32)
    bind = Bindings(jnp.asarray(cols), jnp.ones((8,), bool), jnp.zeros((), bool))
    pat = CompiledPattern(Slot.free(0), Slot.const_(1), Slot.free(0))  # ?x p ?x
    got = hj_ops.match_matrix(bind, kb, pat)
    want = match_matrix_ref(bind.cols, bind.valid, kb.s_ps, kb.p_ps, kb.o_ps,
                            kb.valid, pat)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# closure
# --------------------------------------------------------------------------

@pytest.mark.parametrize("n", [8, 64, 130, 256])
def test_closure_matches_ref(n):
    rng = np.random.default_rng(n)
    adj = (rng.random((n, n)) < 0.05).astype(np.float32)
    got = cl_ops.transitive_closure(jnp.asarray(adj), max_depth=n, use_pallas=True)
    want = closure_ref(jnp.asarray(adj), steps=int(np.ceil(np.log2(max(2, n)))))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want) > 0.5)


def test_closure_chain_depth():
    # a chain 0 -> 1 -> 2 -> ... -> 9: closure must connect 0 to 9
    n = 10
    adj = np.zeros((n, n), np.float32)
    for i in range(n - 1):
        adj[i, i + 1] = 1.0
    reach = np.asarray(cl_ops.transitive_closure(jnp.asarray(adj), max_depth=n))
    assert reach[0, 9] and reach[0, 0] and not reach[9, 0]


def test_closure_cycle_reaches_everything():
    """A directed cycle: every node reaches every node; the squaring
    fixpoint must saturate, not loop or overshoot."""
    n = 6
    adj = np.zeros((n, n), np.float32)
    for i in range(n):
        adj[i, (i + 1) % n] = 1.0
    reach = np.asarray(cl_ops.transitive_closure(jnp.asarray(adj), max_depth=n))
    assert reach.all()
    ids, count = cl_ops.closure_descendants(jnp.asarray(adj), root=2,
                                            out_cap=n, max_depth=n)
    assert int(count) == n
    np.testing.assert_array_equal(np.asarray(ids), np.arange(n))


def test_closure_descendants_empty_and_isolated_root():
    """Zero-edge adjacency: the closure is reflexive only — every root's
    descendant set is exactly itself."""
    for n in (1, 8):
        adj = np.zeros((n, n), np.float32)
        ids, count = cl_ops.closure_descendants(jnp.asarray(adj), root=0,
                                                out_cap=max(n, 2),
                                                max_depth=n)
        assert int(count) == 1
        assert int(np.asarray(ids)[0]) == 0


def test_closure_ancestors_is_transposed_descendants():
    rng = np.random.default_rng(3)
    n = 24
    adj = (rng.random((n, n)) < 0.12).astype(np.float32)
    for root in (0, 5, 17):
        a_ids, a_count = cl_ops.closure_ancestors(
            jnp.asarray(adj), root=root, out_cap=n, max_depth=n)
        d_ids, d_count = cl_ops.closure_descendants(
            jnp.asarray(adj.T), root=root, out_cap=n, max_depth=n)
        assert int(a_count) == int(d_count)
        np.testing.assert_array_equal(np.asarray(a_ids), np.asarray(d_ids))
        # oracle: rows the root reaches in the closure matrix
        reach = np.asarray(cl_ops.transitive_closure(
            jnp.asarray(adj), max_depth=n, use_pallas=False))
        want = np.nonzero(reach[root])[0]
        np.testing.assert_array_equal(
            np.sort(np.asarray(a_ids)[: int(a_count)]), want)


def test_closure_ops_interpret_parity():
    """interpret=True (Pallas interpreter) and interpret=False (compiled)
    must agree bit-for-bit; compiled mode needs a real accelerator, so the
    pair only runs where one is attached."""
    rng = np.random.default_rng(11)
    n = 8
    adj = (rng.random((n, n)) < 0.2).astype(np.float32)
    i_ids, i_count = cl_ops.closure_descendants(
        jnp.asarray(adj), root=1, out_cap=n, max_depth=n, interpret=True)
    try:
        c_ids, c_count = cl_ops.closure_descendants(
            jnp.asarray(adj), root=1, out_cap=n, max_depth=n,
            interpret=False)
        c_ids, c_count = jax.block_until_ready((c_ids, c_count))
    except Exception as e:                       # pragma: no cover - CPU CI
        pytest.skip("interpret=False needs a real accelerator: %r" % (e,))
    np.testing.assert_array_equal(np.asarray(i_ids), np.asarray(c_ids))
    assert int(i_count) == int(c_count)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

ATT_SHAPES = [
    # (b, hq, hk, tq, tk, d)
    (1, 2, 2, 128, 128, 64),      # MHA
    (1, 4, 2, 128, 128, 64),      # GQA 2:1
    (2, 8, 1, 128, 128, 32),      # MQA
    (1, 2, 2, 256, 256, 128),     # multi-block
]


@pytest.mark.parametrize("shape", ATT_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(shape, dtype):
    b, hq, hk, tq, tk, d = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    q = jnp.asarray(rng.standard_normal((b, hq, tq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, hk, tk, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, hk, tk, d)), dtype)
    got = fa_ops.flash_attention(q, k, v, causal=True, bq=64, bk=64)
    want = attention_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


def test_flash_attention_sliding_window():
    b, hq, hk, t, d = 1, 2, 2, 256, 64
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.standard_normal((b, hq, t, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hk, t, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hk, t, d)), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, causal=True, window=64, bq=64, bk=64)
    want = attention_ref(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_attention_decode_offset():
    """Decode shape: one query position attending over a long KV cache."""
    b, hq, hk, tk, d = 2, 4, 2, 512, 64
    rng = np.random.default_rng(11)
    q = jnp.asarray(rng.standard_normal((b, hq, 8, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, hk, tk, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, hk, tk, d)), jnp.float32)
    got = fa_ops.flash_attention(q, k, v, causal=True, q_offset=tk - 8, bq=8, bk=128)
    want = attention_ref(q, k, v, causal=True, q_offset=tk - 8)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------------
# ssd (Mamba-2)
# --------------------------------------------------------------------------

SSD_SHAPES = [
    # (b, t, h, p, g, s, chunk)
    (1, 64, 2, 16, 1, 16, 32),
    (2, 128, 4, 32, 2, 32, 64),
    (1, 96, 2, 64, 1, 128, 32),    # t not multiple of default chunk
]


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_matches_ref(shape):
    b, t, h, p, g, s, chunk = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    x = jnp.asarray(rng.standard_normal((b, t, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, t, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, t, g, s)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, t, g, s)), jnp.float32)
    D = jnp.asarray(rng.standard_normal((h,)), jnp.float32)
    got = ssd_ops.ssd(x, dt, A, Bm, Cm, D, chunk=chunk, use_pallas=True)
    want, _ = ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_ssd_final_state_matches_ref():
    b, t, h, p, g, s, chunk = 1, 64, 2, 16, 1, 16, 16
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((b, t, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, t, h)), jnp.float32)
    A = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = jnp.asarray(rng.standard_normal((b, t, g, s)), jnp.float32)
    Cm = jnp.asarray(rng.standard_normal((b, t, g, s)), jnp.float32)
    from repro.kernels.ssd.kernel import ssd_pallas
    _, state_k = ssd_pallas(x, dt, A, Bm, Cm, chunk=chunk)
    _, state_r = ssd_ref(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(state_k), np.asarray(state_r),
                               rtol=2e-4, atol=2e-4)


# --------------------------------------------------------------------------
# engine integration: scan-method join through the Pallas kernel
# --------------------------------------------------------------------------

def test_engine_kb_join_pallas_path():
    from repro.core import algebra
    rng = np.random.default_rng(5)
    rows = [(int(rng.integers(8000, 8010)), int(rng.integers(1, 3)),
             int(rng.integers(8000, 8010))) for _ in range(40)]
    kb = kb_from_triples(rows, capacity=64)
    cols = rng.integers(8000, 8010, size=(16, 2)).astype(np.uint32)
    bind = Bindings(jnp.asarray(cols), jnp.ones((16,), bool), jnp.zeros((), bool))
    pat = CompiledPattern(Slot.bound(0), Slot.const_(1), Slot.free(1))
    out_pallas = algebra.kb_join_scan(bind, kb, pat, out_cap=128, use_pallas=True)
    out_jnp = algebra.kb_join_scan(bind, kb, pat, out_cap=128, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(out_pallas.cols), np.asarray(out_jnp.cols))
    np.testing.assert_array_equal(np.asarray(out_pallas.valid), np.asarray(out_jnp.valid))
