"""Hypothesis strategies generating random well-formed Query ASTs.

The frontend's totality claim — ``parse_query(serialize_query(q)) == q`` for
*every* AST — is pinned by golden paper queries in tests/test_sparql.py; the
strategies here widen that to the whole grammar: stream/KB patterns,
fixed-length and variable-length (closure) property paths, hierarchy
filters, boolean FILTER trees (via ``st.recursive``/``st.deferred``),
OPTIONAL/UNION groups, CONSTRUCT templates with row nodes, and the SELECT
projection form — all over one small deterministic :class:`GenWorld`
vocab/KB so drawn constants are real interned ids.

Works with real hypothesis and with tests/_hypothesis_fallback.py (the
seeded-fuzz stand-in used when the dep is absent) — conftest.py installs the
fallback before this module imports ``hypothesis.strategies``.
"""
from __future__ import annotations

import hypothesis.strategies as st

from repro.core import query as Q
from repro.core.kb import KnowledgeBase, kb_from_triples
from repro.core.rdf import Vocab
from repro.core.session import MODES, ExecutionConfig


class GenWorld:
    """Deterministic tiny vocab + KB the generated queries range over.

    The subclass graph under ``gk:sub`` deliberately contains a diamond and
    a cycle (C4 <-> C5) so closure paths exercise DAG- and cycle-safety.
    """

    def __init__(self) -> None:
        v = self.vocab = Vocab()
        self.stream_preds = [v.pred("gs:p%d" % i) for i in range(4)]
        self.kb_preds = [v.pred("gk:k%d" % i) for i in range(3)]
        self.type_pred = v.pred("gk:type")
        self.sub_pred = v.pred("gk:sub")
        self.classes = [v.term("gk:C%d" % i) for i in range(6)]
        self.entities = [v.term("gk:e%d" % i) for i in range(8)]
        C, E = self.classes, self.entities
        rows = [
            # diamond: C2 -> {C0, C1} -> C0-root side; plus a 2-cycle
            (C[1], self.sub_pred, C[0]),
            (C[2], self.sub_pred, C[0]),
            (C[3], self.sub_pred, C[1]),
            (C[3], self.sub_pred, C[2]),
            (C[4], self.sub_pred, C[5]),
            (C[5], self.sub_pred, C[4]),
        ]
        for i, e in enumerate(E):
            rows.append((e, self.type_pred, C[i % len(C)]))
            rows.append((e, self.kb_preds[i % len(self.kb_preds)],
                         E[(i + 3) % len(E)]))
        self.kb_rows = rows
        self.kb: KnowledgeBase = kb_from_triples(rows)


WORLD = GenWorld()

_VAR_NAMES = ("a", "b", "c", "x", "y", "z")
_NUM_OPS = ("lt", "le", "gt", "ge", "eq", "ne")


def variables():
    return st.builds(Q.Var, st.sampled_from(_VAR_NAMES))


def kb_consts(world: GenWorld = WORLD):
    return st.builds(Q.Const, st.sampled_from(world.entities + world.classes))


def num_consts():
    # fixed-point ids two decimals deep, negative values included (the
    # NUM_OFFSET zero point): every id formats/parses exactly
    return st.builds(lambda k: Q.Const(Vocab.number(k / 100.0)),
                     st.integers(-999, 999))


def terms(world: GenWorld = WORLD):
    return st.one_of(variables(), kb_consts(world), num_consts())


def stream_patterns(world: GenWorld = WORLD):
    return st.builds(
        Q.Pattern, variables(),
        st.builds(Q.Const, st.sampled_from(world.stream_preds)),
        terms(world), st.just(Q.STREAM),
    )


def kb_patterns(world: GenWorld = WORLD):
    return st.builds(
        Q.Pattern, st.one_of(variables(), kb_consts(world)),
        st.builds(Q.Const, st.sampled_from(world.kb_preds)),
        st.one_of(variables(), kb_consts(world)), st.just(Q.KB),
    )


def paths_kb(world: GenWorld = WORLD):
    return st.builds(
        lambda s, preds, e: Q.PathKB(s, tuple(preds), e),
        st.one_of(variables(), kb_consts(world)),
        st.lists(st.sampled_from(world.kb_preds), min_size=1, max_size=3),
        st.one_of(variables(), kb_consts(world)),
    )


def paths_closure(world: GenWorld = WORLD):
    return st.builds(
        Q.PathClosure, st.one_of(variables(), kb_consts(world)),
        st.sampled_from([world.sub_pred] + world.kb_preds),
        st.one_of(variables(), kb_consts(world)),
        st.integers(0, 1),
    )


def filters_subclass(world: GenWorld = WORLD):
    return st.builds(
        Q.FilterSubclass, st.sampled_from(_VAR_NAMES),
        st.just(world.type_pred), st.just(world.sub_pred),
        st.sampled_from(world.classes),
    )


def filter_leaves(world: GenWorld = WORLD):
    # numeric comparisons (negative literals included) and term equality
    # on IRI ids (=/!= only) — both FilterNum leaves of the boolean grammar
    numeric = st.builds(Q.FilterNum, st.sampled_from(_VAR_NAMES),
                        st.sampled_from(_NUM_OPS),
                        st.builds(lambda k: Vocab.number(k / 100.0),
                                  st.integers(-999, 999)))
    term_eq = st.builds(Q.FilterNum, st.sampled_from(_VAR_NAMES),
                        st.sampled_from(("eq", "ne")),
                        st.sampled_from(world.entities + world.classes))
    return st.one_of(numeric, term_eq)


# boolean FILTER trees: st.deferred breaks the self-reference, st.recursive
# bounds the growth — exactly the combinators the fallback must now cover
filter_exprs = st.deferred(lambda: st.recursive(
    filter_leaves(),
    lambda children: st.one_of(
        st.builds(lambda a: Q.FilterBool("not", (a,)), children),
        st.builds(lambda a, b: Q.FilterBool("and", (a, b)),
                  children, children),
        st.builds(lambda a, b: Q.FilterBool("or", (a, b)),
                  children, children),
        st.builds(lambda a, b, c: Q.FilterBool("or", (a, b, c)),
                  children, children, children),
    ),
    max_leaves=6,
))


def filters_bool():
    # only composite nodes: a bare leaf is a FilterNum where-item, not a tree
    return st.builds(
        lambda kind, a, b: Q.FilterBool(*(("not", (a,)) if kind == "not"
                                          else (kind, (a, b)))),
        st.sampled_from(("and", "or", "not")), filter_exprs, filter_exprs,
    )


def optional_groups(world: GenWorld = WORLD):
    return st.builds(
        lambda ps: Q.OptionalGroup(tuple(ps)),
        st.lists(st.one_of(stream_patterns(world), kb_patterns(world)),
                 min_size=1, max_size=2),
    )


def union_groups(world: GenWorld = WORLD):
    branch = st.lists(st.one_of(stream_patterns(world), kb_patterns(world)),
                      min_size=1, max_size=2)
    return st.builds(
        lambda l, r: Q.UnionGroup(tuple(l), tuple(r)), branch, branch,
    )


def where_items(world: GenWorld = WORLD):
    return st.one_of(
        stream_patterns(world), kb_patterns(world), paths_kb(world),
        paths_closure(world), filters_subclass(world), filter_leaves(),
        filters_bool(), optional_groups(world), union_groups(world),
    )


def select_templates(names, vocab: Vocab):
    """The construct templates the SELECT form lowers to (must mirror the
    parser's synthesis exactly, or parse(serialize(q)) != q)."""
    return tuple(
        Q.ConstructTemplate(Q.RowId(0), Q.Const(vocab.pred("?:" + n)),
                            Q.Var(n))
        for n in names
    )


def step_clauses(capacity: int):
    """``STEP m`` values for a ``[RANGE TRIPLES capacity STEP m]`` clause.

    Covers every regime the window geometry distinguishes: absent (None ->
    tumbling), STEP == RANGE (degenerate overlap, must stay bit-exact with
    tumbling), dividing fractions (50% / 75% overlap) and a ragged
    non-divisor (effective window capacity rounds up to R * m).
    """
    divisors = [capacity, max(1, capacity // 2), max(1, capacity // 4)]
    ragged = max(1, capacity // 3 + 1)
    return st.one_of(
        st.none(),
        st.sampled_from(sorted(set(divisors + [ragged]))),
    )


def sliding_geometries(capacity: int = 48):
    """``(window_capacity, window_step)`` pairs for differential runs."""
    return st.builds(lambda s: (capacity, s), step_clauses(capacity))


def incremental_configs(base: ExecutionConfig):
    """Execution-config variants toggling runtime x incremental: the delta
    evaluator must be a pure execution detail in every mode."""
    return st.builds(
        lambda mode, inc: base.replace(mode=mode, incremental=inc),
        st.sampled_from(MODES), st.booleans(),
    )


@st.composite
def queries(draw, world: GenWorld = WORLD):
    """A random well-formed Query AST (CONSTRUCT or SELECT form)."""
    n_stream = draw(st.integers(1, 2))
    n_other = draw(st.integers(0, 3))
    where = [draw(stream_patterns(world)) for _ in range(n_stream)]
    where += [draw(where_items(world)) for _ in range(n_other)]
    bound = sorted(Q.Query(name="tmp", where=tuple(where),
                           construct=()).variables())
    if not bound:           # all-constant where: bind something projectable
        where.append(Q.Pattern(Q.Var("a"),
                               Q.Const(world.stream_preds[0]),
                               Q.Var("b"), Q.STREAM))
        bound = ["a", "b"]
    if draw(st.booleans()):
        k = draw(st.integers(1, min(3, len(bound))))
        names = tuple(bound[:k])
        return Q.Query(name="genq", where=tuple(where),
                       construct=select_templates(names, world.vocab),
                       select=names)
    n_tpl = draw(st.integers(1, 2))
    construct = []
    for i in range(n_tpl):
        subj = draw(st.one_of(
            st.builds(Q.Var, st.sampled_from(bound)), kb_consts(world),
            st.builds(Q.RowId, st.integers(0, 3))))
        pred = draw(st.one_of(
            st.builds(Q.Var, st.sampled_from(bound)),
            st.builds(Q.Const, st.sampled_from(world.stream_preds))))
        obj = draw(st.one_of(
            st.builds(Q.Var, st.sampled_from(bound)), kb_consts(world),
            num_consts()))
        construct.append(Q.ConstructTemplate(subj, pred, obj))
    return Q.Query(name="genq", where=tuple(where),
                   construct=tuple(construct))
