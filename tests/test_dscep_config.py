"""DSCEP deployment presets: registry sanity + end-to-end via build_runtime."""
import numpy as np
import pytest

from repro.configs import dscep
from repro.core import paper_queries as PQ
from repro.core.rdf import Vocab, to_host_rows
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)


def test_presets_registered():
    names = set(dscep.deployments())
    assert {"paper-eval", "paper-eval-subquery", "paper-eval-auto",
            "smoke", "monolithic"} <= names
    assert dscep.get_deployment("paper-eval").runtime.window_capacity == 1000
    assert dscep.get_deployment("paper-eval-subquery").runtime.kb_method == "probe"
    # the paper's two measured methods stay pinned as baselines; every
    # non-baseline preset deploys the cost-based access planner
    assert dscep.get_deployment("paper-eval").runtime.kb_method == "scan"
    assert dscep.get_deployment("paper-eval-auto").runtime.kb_method == "auto"
    assert dscep.get_deployment("smoke").runtime.kb_method == "auto"
    assert dscep.get_deployment("pipelined").runtime.kb_method == "auto"
    assert not dscep.get_deployment("monolithic").decomposed


def test_build_runtime_smoke_end_to_end():
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(num_artists=16, num_shows=8,
                                      filler_triples=50))
    ts = TweetSchema.create(vocab)
    rows = generate_tweets(vocab, ts, kbd.artist_ids,
                           TweetStreamConfig(num_tweets=16))
    chunks = list(stream_chunks(rows, 256))
    q = PQ.q15(vocab, ts, kbd.schema)

    split = dscep.build_runtime("smoke", q, kbd.kb, vocab)
    mono = dscep.build_runtime("monolithic", q, kbd.kb, vocab)

    def results(rt):
        out = []
        for c in chunks:
            out += [(r[0], r[1], r[2]) for r in to_host_rows(rt.process_chunk(c)[0])]
        return sorted(set(out))

    rs, rm = results(split), results(mono)
    assert len(rs) > 0
    assert rs == rm
