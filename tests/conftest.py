"""Shared fixtures: a small TweetsKB-like stream + DBpedia-like KB world.

NOTE: no XLA_FLAGS manipulation here — tests must see the real single-device
CPU platform (the 512-device trick is exclusively for launch/dryrun.py).

``hypothesis`` is an optional dev dep (requirements-dev.txt); when missing,
a deterministic seeded-fuzz fallback is registered so the nine property-test
modules still collect and run (see tests/_hypothesis_fallback.py).
"""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.dirname(__file__))   # tests/ is not a package
import _hypothesis_fallback                     # noqa: E402

_hypothesis_fallback.install()

from repro.core.rdf import Vocab
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks


class World:
    def __init__(self, num_tweets=40, num_artists=32, filler=200, seed=0):
        self.vocab = Vocab()
        self.kbd = generate_kb(
            self.vocab,
            KBConfig(num_artists=num_artists, num_shows=16, filler_triples=filler, seed=seed),
        )
        self.schema = self.kbd.schema
        self.tweets = TweetSchema.create(self.vocab)
        self.rows = generate_tweets(
            self.vocab, self.tweets, self.kbd.artist_ids,
            TweetStreamConfig(num_tweets=num_tweets, seed=seed),
        )
        self.chunks = list(stream_chunks(self.rows, 256))


@pytest.fixture(scope="session")
def world():
    return World()


@pytest.fixture(scope="session")
def big_world():
    return World(num_tweets=120, num_artists=64, filler=500, seed=1)
