"""The paper's correctness claim: decomposed execution == monolithic execution
("All results are the same when executing CQuery1 with only one C-SPARQL and
when dividing it"), plus KB-pruning soundness and method equivalence — all
driven through the unified Session API.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import query as Q
from repro.core.planner import prune_kb_for
from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks

CFG = ExecutionConfig(window_capacity=128, max_windows=4, bind_cap=512,
                      scan_cap=128, out_cap=512)


def register(world, q, cfg, kb=None):
    return Session(cfg, vocab=world.vocab,
                   kb=kb if kb is not None else world.kbd.kb).register(q)


def q15_query(world):
    ts, kbd, vocab = world.tweets, world.kbd, world.vocab
    return Q.Query(
        name="q15",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("ent"), Q.STREAM),
            Q.FilterSubclass("ent", kbd.schema.rdf_type, kbd.schema.subclass_of,
                             kbd.schema.musical_artist),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"), Q.Const(vocab.pred("out:artistTweet")),
                                Q.Var("ent")),
        ),
    )


def q16_query(world):
    """Property-path query: tweet -> entity -> birthPlace -> country -> code."""
    ts, kbd, vocab = world.tweets, world.kbd, world.vocab
    s = kbd.schema
    return Q.Query(
        name="q16",
        where=(
            Q.Pattern(Q.Var("tweet"), Q.Const(ts.mentions), Q.Var("ent"), Q.STREAM),
            Q.PathKB(Q.Var("ent"), (s.birth_place, s.country, s.country_code),
                     Q.Var("cc")),
        ),
        construct=(
            Q.ConstructTemplate(Q.Var("tweet"), Q.Const(vocab.pred("out:code")),
                                Q.Var("cc")),
        ),
    )


def results(out):
    return sorted(set((r[0], r[1], r[2]) for r in to_host_rows(out)))


def run_both(world, q, cfg=CFG):
    mono = register(world, q, cfg.replace(mode="monolithic"))
    split = register(world, q, cfg.replace(mode="single_program"))
    res_m, res_s = [], []
    for chunk in world.chunks:
        res_m += results(mono.process_chunk(chunk)[0])
        res_s += results(split.process_chunk(chunk)[0])
    return sorted(res_m), sorted(res_s), split


def test_q15_mono_equals_split(world):
    m, s, rt = run_both(world, q15_query(world))
    assert len(m) > 0
    assert m == s


def test_q16_path_mono_equals_split(world):
    m, s, rt = run_both(world, q16_query(world))
    assert len(m) > 0
    assert m == s


def test_used_kb_strictly_smaller(world):
    q = q15_query(world)
    reg = register(world, q, CFG)
    full = int(np.asarray(world.kbd.kb.count()))
    for name, op in reg.operators.items():
        if op.kb is not None:
            used = int(np.asarray(op.kb.count()))
            assert 0 < used < full


def test_kb_pruning_sound(world):
    """Running the monolithic query against its own pruned KB changes nothing."""
    q = q15_query(world)
    pruned = prune_kb_for(q, world.kbd.kb)
    full_rt = register(world, q, CFG.replace(mode="monolithic"))
    pruned_rt = register(world, q, CFG.replace(mode="monolithic"), kb=pruned)
    for chunk in world.chunks:
        assert results(full_rt.process_chunk(chunk)[0]) == \
            results(pruned_rt.process_chunk(chunk)[0])


def test_scan_and_probe_methods_equivalent(world):
    q = q16_query(world)
    rt_scan = register(world, q, CFG.replace(mode="monolithic"))
    rt_probe = register(world, q,
                        CFG.replace(mode="monolithic", kb_method="probe"))
    for chunk in world.chunks:
        assert results(rt_scan.process_chunk(chunk)[0]) == \
            results(rt_probe.process_chunk(chunk)[0])


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 10_000), n_tweets=st.integers(5, 30))
def test_equivalence_property_random_worlds(seed, n_tweets):
    """Property: mono == split across random streams and KBs (both methods)."""
    vocab = Vocab()
    kbd = generate_kb(vocab, KBConfig(num_artists=16, num_shows=8,
                                      filler_triples=50, seed=seed))
    tws = TweetSchema.create(vocab)
    rows = generate_tweets(vocab, tws, kbd.artist_ids,
                           TweetStreamConfig(num_tweets=n_tweets, seed=seed))
    chunks = list(stream_chunks(rows, 256))

    class W:
        pass

    w = W()
    w.vocab, w.kbd, w.tweets, w.chunks = vocab, kbd, tws, chunks
    m, s, _ = run_both(w, q15_query(w))
    assert m == s
