"""Session/ExecutionConfig facade tests.

The acceptance bar of the API redesign: one Session code path constructs,
feeds and drains all three runtimes, and a cquery1 run produces
**bit-identical** output streams across ``monolithic``, ``single_program``
and ``pipelined`` modes.
"""
import warnings

import numpy as np
import pytest

from repro.core import paper_queries as PQ
from repro.core.engine import KBJoin
from repro.core.rdf import Vocab, to_host_rows
from repro.core.session import ExecutionConfig, MODES, Session
from repro.data.dbpedia import KBConfig, generate_kb
from repro.data.tweets import (
    TweetSchema, TweetStreamConfig, generate_tweets, stream_chunks,
)

CFG = ExecutionConfig(window_capacity=96, max_windows=4, bind_cap=1024,
                      scan_cap=128, out_cap=1024, intermediate_cap=512)


class SessWorld:
    def __init__(self, num_tweets=36, seed=0):
        self.vocab = Vocab()
        self.kbd = generate_kb(
            self.vocab,
            KBConfig(num_artists=24, num_shows=12, filler_triples=80,
                     seed=seed),
        )
        self.tweets = TweetSchema.create(self.vocab)
        pool = np.concatenate([self.kbd.artist_ids, self.kbd.show_ids])
        rows = generate_tweets(
            self.vocab, self.tweets, pool,
            TweetStreamConfig(num_tweets=num_tweets, mentions_min=2,
                              mentions_max=3, seed=seed),
        )
        self.chunks = list(stream_chunks(rows, 96))

    def session(self, cfg):
        return Session(cfg, vocab=self.vocab, kb=self.kbd.kb)


@pytest.fixture(scope="module")
def sworld():
    w = SessWorld()
    assert len(w.chunks) >= 3
    return w


def assert_bit_identical(outs_a, outs_b, tag=""):
    assert len(outs_a) == len(outs_b)
    for i, (a, b) in enumerate(zip(outs_a, outs_b)):
        for col, ca, cb in zip(a._fields, a, b):
            assert bool(np.all(np.asarray(ca) == np.asarray(cb))), (
                f"{tag} chunk {i} column {col} diverges")


# --------------------------------------------------------------------------
# the acceptance criterion: one Session, three modes, identical streams
# --------------------------------------------------------------------------

def test_cquery1_bit_identical_across_all_modes(sworld):
    outs = {}
    for mode in MODES:
        reg = sworld.session(CFG.replace(mode=mode)).register(PQ.CQUERY1_RQ)
        outs[mode], overflow = reg.run(sworld.chunks)
        assert not {k: v for k, v in overflow.items() if v}, (mode, overflow)
    assert sum(len(to_host_rows(o)) for o in outs["monolithic"]) > 0
    assert_bit_identical(outs["monolithic"], outs["single_program"],
                         "single_program")
    assert_bit_identical(outs["monolithic"], outs["pipelined"], "pipelined")


def test_register_text_and_ast_agree(sworld):
    q = PQ.cquery1(sworld.vocab, sworld.tweets, sworld.kbd.schema)
    from_text = sworld.session(CFG).register(PQ.CQUERY1_RQ)
    from_ast = sworld.session(CFG).register(q)
    assert from_text.query == from_ast.query
    outs_t, _ = from_text.run(sworld.chunks)
    outs_a, _ = from_ast.run(sworld.chunks)
    assert_bit_identical(outs_t, outs_a, "text vs ast")


def test_stream_generator_matches_run(sworld):
    for mode in MODES:
        reg = sworld.session(CFG.replace(mode=mode)).register(PQ.Q15_RQ)
        ref, _ = reg.run(sworld.chunks)
        reg2 = sworld.session(CFG.replace(mode=mode)).register(PQ.Q15_RQ)
        got = list(reg2.stream(sworld.chunks))
        assert_bit_identical(ref, got, f"stream() {mode}")


def test_abandoned_pipelined_stream_leaves_runtime_clean(sworld):
    """Closing a pipelined stream() generator early must drain the chunks
    it left in flight; the next full stream() on the same handle yields
    exactly len(chunks) outputs, identical to a fresh run."""
    reg = sworld.session(CFG.replace(mode="pipelined")).register(PQ.Q15_RQ)
    gen = reg.stream(sworld.chunks)
    next(gen)
    gen.close()                      # abandon mid-stream
    assert reg.runtime._in_flight == 0
    got = list(reg.stream(sworld.chunks))
    assert len(got) == len(sworld.chunks)
    ref, _ = sworld.session(
        CFG.replace(mode="pipelined")).register(PQ.Q15_RQ).run(sworld.chunks)
    assert_bit_identical(ref, got, "post-abandon stream")


def test_overflow_normalized_per_operator(sworld):
    tiny = CFG.replace(out_cap=16, intermediate_cap=8)
    counts = {}
    for mode in MODES:
        reg = sworld.session(tiny.replace(mode=mode)).register(PQ.CQUERY1_RQ)
        _, overflow = reg.run(sworld.chunks)
        assert all(isinstance(v, int) for v in overflow.values())
        counts[mode] = overflow
        assert sum(overflow.values()) > 0, (mode, "expected clipping")
    # decomposed modes agree operator-by-operator
    assert counts["single_program"] == counts["pipelined"]
    # monolithic reports under the query's own name
    assert set(counts["monolithic"]) == {"cquery1"}


# --------------------------------------------------------------------------
# config consolidation + validation
# --------------------------------------------------------------------------

def test_execution_config_validates_mode_and_mesh():
    with pytest.raises(ValueError, match="unknown mode"):
        ExecutionConfig(mode="warp_speed")
    with pytest.raises(ValueError, match="placement"):
        ExecutionConfig(mode="pipelined", mesh=object())


def test_runtime_config_slice_carries_interpret():
    cfg = ExecutionConfig(use_pallas=True, interpret=False)
    rcfg = cfg.runtime_config()
    assert rcfg.use_pallas and not rcfg.interpret
    assert cfg.runtime_config().fuse_compaction is cfg.fuse_compaction


def test_interpret_knob_reaches_compiled_plan_steps(sworld):
    """The ROADMAP open item: interpret must flow config -> plan -> KBJoin
    without editing kernel source.  (q16 has no FilterSubclass, so plan
    construction stays trace-free and interpret=False builds even on CPU.)"""
    for interp in (True, False):
        cfg = CFG.replace(mode="monolithic", use_pallas=True,
                          fuse_compaction=True, interpret=interp)
        reg = sworld.session(cfg).register(PQ.Q16_RQ)
        steps = [s for s in reg.runtime.operator.plan.steps
                 if isinstance(s, KBJoin)]
        assert steps and all(s.interpret is interp for s in steps)
        assert all(s.use_pallas for s in steps)


def test_register_duplicate_name_raises_with_both_texts(sworld):
    """Registering a second query under an existing name is almost always a
    caller bug (silently dropping a standing query); the error carries both
    serializations so the collision is diagnosable, and ``replace=True``
    opts into substitution."""
    sess = sworld.session(CFG)
    first = sess.register(PQ.CQUERY1_RQ)
    with pytest.raises(ValueError, match="already registered") as ei:
        sess.register(PQ.CQUERY1_RQ)
    msg = str(ei.value)
    assert "existing:" in msg and "new:" in msg
    assert msg.count(first.text.strip().splitlines()[0]) >= 1
    assert "replace=True" in msg
    assert sess.queries["cquery1"] is first      # registration untouched
    second = sess.register(PQ.CQUERY1_RQ, replace=True)
    assert sess.queries["cquery1"] is second and second is not first
    outs_a, _ = first.run(sworld.chunks[:1])
    outs_b, _ = second.run(sworld.chunks[:1])
    assert_bit_identical(outs_a, outs_b, "replace")


def test_kb_required_for_kb_touching_query(sworld):
    sess = Session(CFG, vocab=sworld.vocab, kb=None)
    with pytest.raises(ValueError, match="no kb= attached"):
        sess.register(PQ.Q15_RQ)


def test_registered_query_text_round_trips(sworld):
    reg = sworld.session(CFG).register(PQ.CQUERY1_RQ)
    from repro.core.sparql import parse_query
    assert parse_query(reg.text, sworld.vocab) == reg.query


def test_window_geometry_reports_step_even_without_range_applied(sworld):
    """A registration carrying ``STEP`` reports it in window_geometry even
    when window_from_query=False leaves the config capacity in force —
    the geometry is what the query *declared*, not only what was applied."""
    reg = sworld.session(CFG).register(PQ.Q15_RQ)       # ... STEP 1]
    assert reg.window_geometry == (CFG.window_capacity, 1)
    # window_from_query=True applies both numbers from the clause
    applied = sworld.session(
        CFG.replace(window_from_query=True)).register(PQ.Q15_RQ)
    assert applied.window_geometry == (1000, 1)
    # a config-level step shows through for STEP-less query text
    stepped = sworld.session(CFG.replace(window_step=32))
    q = PQ.q15(sworld.vocab, sworld.tweets, sworld.kbd.schema)
    assert stepped.register(q).window_geometry == (CFG.window_capacity, 32)


def test_text_round_trips_step_without_effect(sworld):
    """serialize_query(info=) keeps the STEP clause verbatim even when the
    registration did not apply it (window_from_query=False)."""
    from repro.core.sparql import parse_query_info
    reg = sworld.session(CFG).register(PQ.Q15_RQ)
    assert "[RANGE TRIPLES 1000 STEP 1]" in reg.text
    q2, info2 = parse_query_info(reg.text, sworld.vocab)
    assert q2 == reg.query
    assert (info2.window_triples, info2.window_step) == (1000, 1)


# --------------------------------------------------------------------------
# deprecation shims
# --------------------------------------------------------------------------

def test_direct_runtime_construction_warns(sworld):
    from repro.core.pipeline import PipelinedRuntime
    from repro.core.planner import decompose
    from repro.core.runtime import (
        DSCEPRuntime, MonolithicRuntime, RuntimeConfig,
    )

    q = PQ.q15(sworld.vocab, sworld.tweets, sworld.kbd.schema)
    rcfg = RuntimeConfig(window_capacity=96, max_windows=4, bind_cap=512,
                         scan_cap=128, out_cap=512)
    dag = decompose(q, sworld.vocab)
    for ctor in (
        lambda: MonolithicRuntime(q, sworld.kbd.kb, rcfg),
        lambda: DSCEPRuntime(dag, sworld.kbd.kb, sworld.vocab, rcfg),
        lambda: PipelinedRuntime(dag, sworld.kbd.kb, sworld.vocab, rcfg),
    ):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ctor()
        assert any(issubclass(x.category, DeprecationWarning) for x in w)


def test_session_construction_does_not_warn(sworld):
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        for mode in MODES:
            sworld.session(CFG.replace(mode=mode)).register(PQ.Q15_RQ)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)], (
        [str(x.message) for x in w])
