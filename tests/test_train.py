"""Training substrate: optimizer, gradient compression (property-based),
checkpoint/restart (atomicity + elastic restore), the ElasticRunner's
failure/straggler machinery, and loss-goes-down on a tiny model."""
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config, smoke_variant
from repro.data.tokens import TokenDatasetConfig, batch_at_step
from repro.models import lm
from repro.train.checkpoint import CheckpointManager
from repro.train.elastic import ElasticConfig, ElasticRunner
from repro.train.grad_compress import (
    dequantize_int8, make_compressed_allreduce, quantize_int8,
)
from repro.train.optimizer import (
    AdamWConfig, adamw_update, init_opt_state, lr_at,
)
from repro.train.train_loop import TrainConfig, make_train_step


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_lr_schedule_warmup_then_cosine():
    cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_at(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[5] < lrs[9]                  # warming up
    assert abs(lrs[10] - 1e-3) / 1e-3 < 0.05          # peak reached
    assert lrs[99] <= 0.11 * 1e-3                     # decayed to the floor
    assert lrs[99] >= 0.09 * 1e-3                     # min_lr_ratio respected


def test_adamw_moves_towards_minimum():
    params = {"w": jnp.asarray([4.0, -3.0])}
    opt = init_opt_state(params)
    cfg = AdamWConfig(peak_lr=0.1, warmup_steps=1, total_steps=200,
                      weight_decay=0.0)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}               # d/dw (w^2)
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.5


# --------------------------------------------------------------------------
# gradient compression (int8 + error feedback)
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=64))
def test_int8_roundtrip_error_bounded(vals):
    x = jnp.asarray(np.asarray(vals, np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    # quantization error bounded by half a step
    assert float(err.max()) <= float(scale) * 0.5 + 1e-5


def test_error_feedback_preserves_signal():
    """With EF, the *accumulated* applied gradient tracks the true sum even
    when single-step quantization is coarse."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=256).astype(np.float32))
    resid = jnp.zeros_like(g_true)
    applied = jnp.zeros_like(g_true)
    for _ in range(50):
        gc = g_true + resid
        q, s = quantize_int8(gc)
        dec = dequantize_int8(q, s)
        resid = gc - dec
        applied = applied + dec
    # mean applied per step ~ true gradient
    np.testing.assert_allclose(np.asarray(applied / 50), np.asarray(g_true),
                               atol=float(s) * 0.6)


def test_compressed_allreduce_single_device_mesh():
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    allreduce = make_compressed_allreduce(mesh, "data")

    from repro.compat import shard_map
    from jax.sharding import PartitionSpec as P

    def run(g, r):
        return allreduce({"g": g}, {"g": r})

    f = shard_map(run, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                  check_vma=False)
    g = jnp.asarray(np.linspace(-1, 1, 32, dtype=np.float32))
    mean, resid = f(g, jnp.zeros_like(g))
    np.testing.assert_allclose(np.asarray(mean["g"]), np.asarray(g), atol=0.02)
    # residual = what quantization lost
    np.testing.assert_allclose(
        np.asarray(mean["g"] + resid["g"]), np.asarray(g), atol=1e-6)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------

@pytest.fixture
def ckpt_dir():
    d = tempfile.mkdtemp(prefix="repro_ckpt_test_")
    yield d
    shutil.rmtree(d, ignore_errors=True)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
        "nested": {"b": jnp.asarray(rng.integers(0, 10, size=(3,)))},
    }


def test_checkpoint_roundtrip(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir, keep=2)
    t = _tree(0)
    mgr.save(7, t, mesh_shape={"data": 1}, blocking=True)
    restored, manifest = mgr.restore(jax.tree.map(jnp.zeros_like, t))
    assert manifest["step"] == 7
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), t, restored)


def test_checkpoint_retention_and_latest(ckpt_dir):
    mgr = CheckpointManager(ckpt_dir, keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree(s), blocking=True)
    assert mgr.steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_checkpoint_atomic_no_partial_dir(ckpt_dir):
    """A stale .tmp dir (simulated crash) is never listed as a checkpoint."""
    mgr = CheckpointManager(ckpt_dir, keep=3)
    mgr.save(1, _tree(1), blocking=True)
    os.makedirs(os.path.join(ckpt_dir, "step_00000002.tmp"))
    assert mgr.steps() == [1]
    restored, manifest = mgr.restore(jax.tree.map(jnp.zeros_like, _tree(1)))
    assert manifest["step"] == 1


def test_checkpoint_elastic_restore_shardings(ckpt_dir):
    """Restore with explicit shardings (the elastic path) places leaves on
    the current mesh regardless of the writing mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mgr = CheckpointManager(ckpt_dir, keep=1)
    t = _tree(3)
    mgr.save(5, t, mesh_shape={"data": 512}, blocking=True)   # "pod" run
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, manifest = mgr.restore(jax.tree.map(jnp.zeros_like, t),
                                     shardings=sh)
    assert manifest["mesh_shape"] == {"data": 512}
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), t, restored)


# --------------------------------------------------------------------------
# elastic runner
# --------------------------------------------------------------------------

def test_elastic_runner_recovers_and_resizes(ckpt_dir):
    cfg = ElasticConfig(max_restarts=2, checkpoint_every=100)
    runner = ElasticRunner(cfg, None, [{"data": 16}, {"data": 8}])
    saves = {}

    def step_fn(state, step):
        return state + 1, {"loss": 1.0 / (step + 1)}

    def save_fn(state, step):
        saves["latest"] = (state, step)

    def restore_fn():
        return saves.get("latest", (0, 0))

    failures = {3: RuntimeError("node lost"), 5: RuntimeError("node lost"),
                6: RuntimeError("node lost")}
    state, history = runner.run(0, step_fn, 0, 10, save_fn, restore_fn,
                                failure_schedule=failures)
    # every step index eventually completed (restarts replay from the ckpt,
    # so some steps ran more than once)
    assert {r.step for r in history} == set(range(10))
    assert history[-1].step == 9
    # second failure hit max_restarts=2 -> resized down the preference list
    assert runner.current_mesh_shape() == {"data": 8}


def test_elastic_runner_flags_straggler():
    import time as _time
    cfg = ElasticConfig(straggler_factor=2.5, checkpoint_every=100)
    runner = ElasticRunner(cfg, None, [{"data": 1}])

    def step_fn(state, step):
        _time.sleep(0.08 if step == 5 else 0.005)
        return state, {"loss": 0.5}

    _, history = runner.run(0, step_fn, 0, 8, lambda *_: None, lambda: (0, 0))
    stragglers = [r.step for r in history if r.straggler]
    assert stragglers == [5]


# --------------------------------------------------------------------------
# end-to-end: loss decreases on a tiny model
# --------------------------------------------------------------------------

def test_train_loss_decreases_tiny_model():
    cfg = smoke_variant(get_config("olmo-1b"))
    tcfg = TrainConfig(opt=AdamWConfig(peak_lr=3e-3, warmup_steps=2,
                                       total_steps=30))
    step = jax.jit(make_train_step(cfg, tcfg))
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    dcfg = TokenDatasetConfig(vocab_size=cfg.vocab_size, seq_len=32,
                              global_batch=4)
    losses = []
    for s in range(12):
        batch = {k: jnp.asarray(v) for k, v in batch_at_step(dcfg, 0).items()}
        params, opt, m = step(params, opt, batch)   # same batch: must overfit
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_microbatch_accumulation_matches_full_batch():
    """grad accumulation over k microbatches == one full-batch step."""
    cfg = smoke_variant(get_config("qwen2-1.5b"))
    opt_cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=1, total_steps=10)
    params, _ = lm.init_model(jax.random.PRNGKey(0), cfg)
    dcfg = TokenDatasetConfig(vocab_size=cfg.vocab_size, seq_len=16,
                              global_batch=8)
    batch = {k: jnp.asarray(v) for k, v in batch_at_step(dcfg, 0).items()}

    step1 = jax.jit(make_train_step(cfg, TrainConfig(opt=opt_cfg, microbatches=1)))
    step4 = jax.jit(make_train_step(cfg, TrainConfig(opt=opt_cfg, microbatches=4)))
    p1, _, m1 = step1(params, init_opt_state(params), batch)
    p4, _, m4 = step4(params, init_opt_state(params), batch)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))), p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-2   # bf16 accumulation tolerance
